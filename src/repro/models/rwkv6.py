"""RWKV6 ("Finch") — attention-free LM with data-dependent decay.

Time-mix: token-shift with LoRA-dynamic mixing coefficients, per-channel
data-dependent decay ``w_t = exp(-exp(logit))``, bonus ``u``, and the WKV
linear-attention state ``S in [B,H,hd_k,hd_v]``.

Training uses a chunked-parallel WKV: chunks of ``rwkv_chunk`` tokens; the
intra-chunk part is computed pairwise in a ``lax.scan`` step (all decay
exponents are differences of a decreasing cumulative log-decay, so every
``exp`` argument is <= 0 — numerically safe without clamping); the cross-chunk
part is the S recurrence carried by the same scan.  Decode is the O(1)
recurrence, which is what makes ``long_500k`` runnable for this arch.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import nn
from .config import ModelConfig


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def _heads(cfg: ModelConfig):
    hd = cfg.rwkv_head_dim
    H = cfg.d_model // hd
    return H, hd


def rwkv_block_init(key, cfg: ModelConfig):
    d, ff = cfg.d_model, cfg.d_ff
    H, hd = _heads(cfg)
    Lm, Ld = cfg.rwkv_lora_mix, cfg.rwkv_lora_decay
    dt = cfg.pdtype
    ks = jax.random.split(key, 12)
    att = {
        "ln": nn.layernorm_init(d, dtype=dt),
        "maa_x": nn.Px(jnp.zeros((d,), dt), ("embed",)),
        "maa": nn.Px(jnp.zeros((5, d), dt), ("mix5", "embed")),
        "tm_A": nn.Px(nn.lecun_init(ks[0], (d, 5 * Lm), dt, d), ("embed", "lora")),
        "tm_B": nn.Px(nn.normal_init(ks[1], (5, Lm, d), dt, 0.01),
                      ("mix5", "lora", "embed")),
        "r": nn.linear_init(ks[2], d, d, axes=("embed", "wkv_proj"), dtype=dt),
        "k": nn.linear_init(ks[3], d, d, axes=("embed", "wkv_proj"), dtype=dt),
        "v": nn.linear_init(ks[4], d, d, axes=("embed", "wkv_proj"), dtype=dt),
        "g": nn.linear_init(ks[5], d, d, axes=("embed", "wkv_proj"), dtype=dt),
        "decay_base": nn.Px(jnp.full((d,), -1.0, jnp.float32), ("wkv_proj",)),
        "dec_A": nn.Px(nn.lecun_init(ks[6], (d, Ld), dt, d), ("embed", "lora")),
        "dec_B": nn.Px(nn.normal_init(ks[7], (Ld, d), dt, 0.01), ("lora", "wkv_proj")),
        "u": nn.Px(jnp.zeros((d,), jnp.float32), ("wkv_proj",)),
        "ln_x": nn.layernorm_init(d, axis="wkv_proj", dtype=dt),
        "o": nn.linear_init(ks[8], d, d, axes=("wkv_proj", "embed"), dtype=dt),
    }
    ffn = {
        "ln": nn.layernorm_init(d, dtype=dt),
        "maa_k": nn.Px(jnp.zeros((d,), dt), ("embed",)),
        "maa_r": nn.Px(jnp.zeros((d,), dt), ("embed",)),
        "k": nn.linear_init(ks[9], d, ff, axes=("embed", "mlp"), dtype=dt),
        "v": nn.linear_init(ks[10], ff, d, axes=("mlp", "embed"), dtype=dt),
        "r": nn.linear_init(ks[11], d, d, axes=("embed", "wkv_proj"), dtype=dt),
    }
    return {"att": att, "ffn": ffn}


# ---------------------------------------------------------------------------
# WKV core
# ---------------------------------------------------------------------------


def wkv_chunked(r, k, v, lw, u, chunk: int, s0=None):
    """Chunked WKV6.

    r,k,v [B,T,H,hd]; lw = log-decay [B,T,H,hd] (<= 0); u [H,hd].
    Returns (y [B,T,H,hd], s_final [B,H,hd,hd]).
    """
    B, T, H, hd = r.shape
    L = min(chunk, T)
    T0 = T
    if T % L:  # pad with k=v=r=0, lw=0 (decay 1): exact, state-preserving
        pad = L - T % L
        z = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = (jnp.pad(a, z) for a in (r, k, v))
        lw = jnp.pad(lw, z)
        T = T + pad
    nc = T // L
    f32 = jnp.float32

    def cshape(x):
        return jnp.moveaxis(x.reshape(B, nc, L, H, hd), 1, 0)  # [nc,B,L,H,hd]

    rc, kc, vc = cshape(r.astype(f32)), cshape(k.astype(f32)), cshape(v.astype(f32))
    lwc = cshape(lw.astype(f32))
    s_init = jnp.zeros((B, H, hd, hd), f32) if s0 is None else s0.astype(f32)
    tri_lower = jnp.tril(jnp.ones((L, L), bool), k=-1)  # strictly lower (j<t)

    def step(S, inp):
        rb, kb, vb, lwb = inp  # [B,L,H,hd]
        cum = jnp.cumsum(lwb, axis=1)  # inclusive, decreasing
        cum_prev = cum - lwb  # cumulative through t-1 (exclusive)
        # intra-chunk pairwise: A[t,j] = sum_a r_t[a] k_j[a] exp(cum_prev_t[a]-cum_j[a])  (j<t)
        diff = cum_prev[:, :, None] - cum[:, None, :]  # [B,t,j,H,hd]
        dec = jnp.exp(jnp.where(tri_lower[None, :, :, None, None], diff, 0.0))
        dec = dec * tri_lower[None, :, :, None, None]
        A = jnp.einsum("btha,btjha,bjha->bthj",
                       rb, dec.astype(f32), kb)
        # diagonal (bonus) term: j == t with u
        diag = jnp.einsum("btha,ha,btha->bth", rb, u.astype(f32), kb)
        y = jnp.einsum("bthj,bjhv->bthv", A, vb)
        y = y + diag[..., None] * vb
        # inter-chunk: y += (r_t . exp(cum_prev_t)) @ S
        r_in = rb * jnp.exp(cum_prev)
        y = y + jnp.einsum("btha,bhav->bthv", r_in, S)
        # state update: S' = diag(exp(cum_L)) S + sum_j (k_j exp(cum_L - cum_j)) (x) v_j
        end = cum[:, -1:, :]  # [B,1,H,hd]
        k_out = kb * jnp.exp(end - cum)
        S_new = jnp.exp(end[:, 0])[:, :, :, None] * S + jnp.einsum(
            "bjha,bjhv->bhav", k_out, vb)
        return S_new, y

    s_final, ys = jax.lax.scan(step, s_init, (rc, kc, vc, lwc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, hd)[:, :T0]
    return y.astype(r.dtype), s_final


def wkv_recurrent(r, k, v, lw, u, s0=None):
    """Step-by-step oracle. Same returns as wkv_chunked."""
    B, T, H, hd = r.shape
    f32 = jnp.float32
    S = jnp.zeros((B, H, hd, hd), f32) if s0 is None else s0.astype(f32)

    def step(S, inp):
        r_t, k_t, v_t, lw_t = (x.astype(f32) for x in inp)  # [B,H,hd]
        S_new, y = wkv_step(S, r_t, k_t, v_t, lw_t, u)
        return S_new, y

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (r, k, v, lw))
    S, ys = jax.lax.scan(step, S, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), S


def wkv_step(S, r_t, k_t, v_t, lw_t, u):
    """One WKV step. S [B,H,hd,hd]; r/k/v/lw [B,H,hd]; u [H,hd]."""
    f32 = jnp.float32
    r_t, k_t, v_t, lw_t = (x.astype(f32) for x in (r_t, k_t, v_t, lw_t))
    kv = jnp.einsum("bha,bhv->bhav", k_t, v_t)
    y = jnp.einsum("bha,bhav->bhv", r_t, S + u.astype(f32)[None, :, :, None] * kv)
    S_new = jnp.exp(lw_t)[..., None] * S + kv
    return S_new, y


# ---------------------------------------------------------------------------
# Block apply
# ---------------------------------------------------------------------------


def _token_shift(x, shift_state=None):
    """Previous token (zeros at position 0 or shift_state). x [B,T,d]."""
    if shift_state is None:
        prev = jnp.pad(x[:, :-1], ((0, 0), (1, 0), (0, 0)))
    else:
        prev = jnp.concatenate([shift_state[:, None, :], x[:, :-1]], axis=1)
    return prev


def _dynamic_mix(p, x, xx):
    """RWKV6 LoRA token-shift mixing -> 5 mixed streams (w,k,v,r,g)."""
    dx = xx - x
    xxx = x + dx * p["maa_x"].astype(x.dtype)[None, None, :]
    B, T, d = x.shape
    lora = jnp.tanh(xxx @ p["tm_A"].astype(x.dtype))  # [B,T,5*Lm]
    lora = lora.reshape(B, T, 5, -1)
    dyn = jnp.einsum("btml,mld->mbtd", lora, p["tm_B"].astype(x.dtype))
    maa = p["maa"].astype(x.dtype)  # [5,d]
    mixed = x[None] + dx[None] * (maa[:, None, None, :] + dyn)
    return mixed  # [5,B,T,d] order: w,k,v,r,g


def time_mix_apply(p, x, cfg: ModelConfig, *, state=None, chunked=True):
    """Time-mix sub-block. state: {"shift": [B,d], "wkv": [B,H,hd,hd]}."""
    H, hd = _heads(cfg)
    B, T, d = x.shape
    shift = state["shift"] if state is not None else None
    xx = _token_shift(x, shift)
    xw, xk, xv, xr, xg = _dynamic_mix(p, x, xx)
    cd = cfg.cdtype
    r = nn.linear_apply(p["r"], xr, cd).reshape(B, T, H, hd)
    k = nn.linear_apply(p["k"], xk, cd).reshape(B, T, H, hd)
    v = nn.linear_apply(p["v"], xv, cd).reshape(B, T, H, hd)
    g = jax.nn.silu(nn.linear_apply(p["g"], xg, cd))
    # data-dependent decay (per channel)
    dec = p["decay_base"].astype(jnp.float32) + (
        jnp.tanh(xw.astype(jnp.float32) @ p["dec_A"].astype(jnp.float32))
        @ p["dec_B"].astype(jnp.float32))
    lw = -jnp.exp(dec).reshape(B, T, H, hd)  # log w <= 0... (w = exp(-exp(dec)))
    u = p["u"].astype(jnp.float32).reshape(H, hd)
    s0 = state["wkv"] if state is not None else None
    if chunked:
        y, s_final = wkv_chunked(r, k, v, lw, u, cfg.rwkv_chunk, s0=s0)
    else:
        y, s_final = wkv_recurrent(r, k, v, lw, u, s0=s0)
    y = y.reshape(B, T, d)
    # per-head group norm
    yh = y.reshape(B, T, H, hd)
    mu = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 64e-5)
    y = yh.reshape(B, T, d) * p["ln_x"]["scale"].astype(y.dtype) + \
        p["ln_x"]["bias"].astype(y.dtype)
    y = nn.linear_apply(p["o"], y * g, cd)
    new_state = {"shift": x[:, -1, :], "wkv": s_final}
    return y, new_state


def channel_mix_apply(p, x, cfg: ModelConfig, *, state=None):
    """Channel-mix (squared-relu FFN with receptance gate)."""
    shift = state["shift"] if state is not None else None
    xx = _token_shift(x, shift)
    dx = xx - x
    xk = x + dx * p["maa_k"].astype(x.dtype)[None, None, :]
    xr = x + dx * p["maa_r"].astype(x.dtype)[None, None, :]
    cd = cfg.cdtype
    k = nn.linear_apply(p["k"], xk, cd)
    k = nn.squared_relu(k)
    kv = nn.linear_apply(p["v"], k, cd)
    out = jax.nn.sigmoid(nn.linear_apply(p["r"], xr, cd)) * kv
    return out, {"shift": x[:, -1, :]}


def rwkv_block_apply(p, x, cfg: ModelConfig, *, state=None, chunked=True):
    att_state = state["att"] if state is not None else None
    ffn_state = state["ffn"] if state is not None else None
    h = nn.layernorm_apply(p["att"]["ln"], x, cfg.norm_eps)
    dy, new_att = time_mix_apply(p["att"], h, cfg, state=att_state,
                                 chunked=chunked)
    x = x + dy
    h = nn.layernorm_apply(p["ffn"]["ln"], x, cfg.norm_eps)
    dy, new_ffn = channel_mix_apply(p["ffn"], h, cfg, state=ffn_state)
    x = x + dy
    return x, {"att": new_att, "ffn": new_ffn}


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


def rwkv_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    dt = cfg.pdtype
    layer_keys = jax.random.split(ks[1], cfg.n_layers)
    blocks = [rwkv_block_init(layer_keys[i], cfg) for i in range(cfg.n_layers)]
    return {
        "embed": nn.embedding_init(ks[0], cfg.vocab, cfg.d_model, dtype=dt),
        "ln_in": nn.layernorm_init(cfg.d_model, dtype=dt),
        "blocks": nn.stack_layers(blocks),
        "ln_f": nn.layernorm_init(cfg.d_model, dtype=dt),
        "unembed": nn.linear_init(ks[2], cfg.d_model, cfg.vocab,
                                  axes=("embed", "vocab"), dtype=dt),
    }


def _empty_state(cfg: ModelConfig, batch: int):
    H, hd = _heads(cfg)
    d = cfg.d_model
    return {
        "att": {"shift": jnp.zeros((batch, d), cfg.cdtype),
                "wkv": jnp.zeros((batch, H, hd, hd), jnp.float32)},
        "ffn": {"shift": jnp.zeros((batch, d), cfg.cdtype)},
    }


def rwkv_forward(p, batch, cfg: ModelConfig, *, mesh=None):
    from . import transformer as tfm

    x = nn.embedding_apply(p["embed"], batch["tokens"], cfg.cdtype, mesh=mesh)
    x = nn.layernorm_apply(p["ln_in"], x, cfg.norm_eps)
    aspec = nn.batch_pspec(mesh, x.shape[0])
    x = nn.constrain(x, mesh, aspec)

    def body(x, bp):
        x = nn.constrain(x, mesh, aspec)
        y, _ = rwkv_block_apply(bp, x, cfg)
        return nn.constrain(y, mesh, aspec), None

    x, _ = jax.lax.scan(tfm.remat_wrap(body, cfg), x, p["blocks"])
    x = nn.layernorm_apply(p["ln_f"], x, cfg.norm_eps)
    logits = nn.linear_apply(p["unembed"], x, jnp.float32)
    if mesh is not None:
        from jax.sharding import PartitionSpec as P

        logits = nn.constrain(
            logits, mesh,
            P(aspec[0], None, "model" if "model" in mesh.axis_names else None))
    return logits, jnp.zeros((), jnp.float32)


def rwkv_loss(p, batch, cfg: ModelConfig, *, mesh=None):
    from . import transformer as tfm

    logits, aux = rwkv_forward(p, batch, cfg, mesh=mesh)
    return tfm._ce_from_logits(logits, batch, aux, cfg, mesh=mesh)


def rwkv_prefill(p, batch, cfg: ModelConfig, *, max_len: int = 0, mesh=None):
    """Prefill = full forward collecting per-layer states (no KV cache)."""
    x = nn.embedding_apply(p["embed"], batch["tokens"], cfg.cdtype, mesh=mesh)
    x = nn.layernorm_apply(p["ln_in"], x, cfg.norm_eps)
    B = x.shape[0]
    init = _empty_state(cfg, B)

    def body(x, bp):
        y, st = rwkv_block_apply(bp, x, cfg, state=init)
        return y, st

    x, states = jax.lax.scan(body, x, p["blocks"])
    x = nn.layernorm_apply(p["ln_f"], x, cfg.norm_eps)
    logits = nn.linear_apply(p["unembed"], x[:, -1:, :], jnp.float32)[:, 0]
    return states, logits


def rwkv_decode_step(p, cache, tokens, cfg: ModelConfig, *, mesh=None):
    x = nn.embedding_apply(p["embed"], tokens[:, None], cfg.cdtype, mesh=mesh)
    x = nn.layernorm_apply(p["ln_in"], x, cfg.norm_eps)

    def body(x, inp):
        bp, st = inp
        y, st2 = rwkv_block_apply(bp, x, cfg, state=st, chunked=False)
        return y, st2

    x, new_states = jax.lax.scan(body, x, (p["blocks"], cache))
    x = nn.layernorm_apply(p["ln_f"], x, cfg.norm_eps)
    logits = nn.linear_apply(p["unembed"], x, jnp.float32)[:, 0]
    return new_states, logits
