"""Fine-grained mixture-of-experts FFN (deepseek-moe / moonlight style).

Routing: softmax over all experts -> top-k -> renormalize.  Dispatch is
capacity-based (dropped-token MoE): tokens are scattered into a per-expert
``[n_local_experts, capacity, d]`` buffer and the expert FFN runs as one
batched matmul — MXU-shaped, no ragged ops on the hot path.

Expert parallelism: under tensor parallelism the block input is *replicated*
over the ``model`` mesh axis, so EP needs **no all_to_all** — each model-axis
device runs the experts it owns over all locally-visible tokens and a single
``psum`` over ``model`` combines expert outputs (same collective cost as a
dense TP MLP).  Implemented with ``jax.shard_map``; gating/aux-loss run
outside in plain GSPMD.

Shared experts (deepseek: 2) are a dense TP MLP with ``ff = n_shared * d_ff``.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import nn
from .config import ModelConfig

BATCH_AXES = ("pod", "data")


def moe_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = cfg.pdtype
    # expert-internal dims get their own (replicated) logical axes: the
    # expert dim itself carries the "model" sharding (EP), so d/ff must not
    # also map to "model"
    p = {
        "router": {
            "w": nn.Px(nn.lecun_init(ks[0], (d, E), jnp.float32, d),
                       ("embed", "router_experts")),
        },
        "up": nn.Px(nn.lecun_init(ks[1], (E, d, ff), dt, d),
                    ("experts", "expert_in", "expert_ff")),
        "down": nn.Px(nn.lecun_init(ks[2], (E, ff, d), dt, ff),
                      ("experts", "expert_ff", "expert_in")),
    }
    if cfg.gated_mlp:
        p["gate"] = nn.Px(nn.lecun_init(ks[3], (E, d, ff), dt, d),
                          ("experts", "expert_in", "expert_ff"))
    if cfg.n_shared_experts > 0:
        p["shared"] = nn.mlp_init(ks[4], d, cfg.n_shared_experts * ff,
                                  gated=cfg.gated_mlp, dtype=dt)
    return p


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------


def route(router_w, x_flat, cfg: ModelConfig):
    """Returns (weights [T,k], idx [T,k], aux_loss scalar)."""
    logits = x_flat.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # [T, E]
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # load-balancing aux (Switch-style): E * sum_e f_e * p_e
    E = cfg.n_experts
    T = x_flat.shape[0]
    f = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    f = f / (T * cfg.top_k)
    pbar = probs.mean(axis=0)
    aux = E * jnp.sum(f * pbar)
    return top_p, top_i, aux


def _capacity(T: int, cfg: ModelConfig, decode: bool) -> int:
    cf = max(cfg.decode_capacity_factor, cfg.capacity_factor) if decode \
        else cfg.capacity_factor
    c = math.ceil(T * cfg.top_k / cfg.n_experts * cf)
    return max(1, c)


# ---------------------------------------------------------------------------
# Expert compute over a local expert range
# ---------------------------------------------------------------------------


def _expert_compute(x_flat, top_w, top_i, up, gate, down, *, expert_offset,
                    n_local: int, capacity: int, cfg: ModelConfig):
    """Dropped-token expert FFN over experts [offset, offset+n_local).

    x_flat [T,d]; top_w/top_i [T,k]; up/gate [El,d,ff], down [El,ff,d].
    Returns y_flat [T,d] (only local experts' contributions).
    """
    T, d = x_flat.shape
    k = top_i.shape[1]
    E = cfg.n_experts
    C = capacity
    cd = cfg.cdtype

    flat_e = top_i.reshape(-1)  # [T*k] global expert ids
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_w = top_w.reshape(-1)

    # rank of each assignment within its (global) expert group
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank_sorted = jnp.arange(T * k) - starts[sorted_e]
    rank = jnp.zeros((T * k,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))

    local_e = flat_e - expert_offset
    is_local = (local_e >= 0) & (local_e < n_local)
    keep = is_local & (rank < C)
    dest = jnp.where(keep, local_e * C + rank, n_local * C)  # drop row at end

    buf = jnp.zeros((n_local * C + 1, d), cd)
    buf = buf.at[dest].set(x_flat.astype(cd)[flat_t])
    h_in = buf[: n_local * C].reshape(n_local, C, d)

    up_h = jnp.einsum("ecd,edf->ecf", h_in, up.astype(cd))
    if gate is not None:
        act = nn.ACTIVATIONS[cfg.activation]
        h = act(jnp.einsum("ecd,edf->ecf", h_in, gate.astype(cd))) * up_h
    else:
        h = nn.ACTIVATIONS[cfg.activation](up_h)
    out = jnp.einsum("ecf,efd->ecd", h, down.astype(cd))  # [El,C,d]
    out = out.reshape(n_local * C, d)
    out = jnp.concatenate([out, jnp.zeros((1, d), cd)], axis=0)

    contrib = out[dest] * flat_w.astype(cd)[:, None] * keep.astype(cd)[:, None]
    y = jnp.zeros((T, d), cd).at[flat_t].add(contrib)
    return y


# ---------------------------------------------------------------------------
# Public apply
# ---------------------------------------------------------------------------


def moe_apply(p, x, cfg: ModelConfig, *, mesh=None, decode: bool = False):
    """x [B,S,d] -> (y [B,S,d], aux scalar)."""
    B, S, d = x.shape
    T = B * S
    x_flat = x.reshape(T, d)
    top_w, top_i, aux = route(p["router"]["w"], x_flat, cfg)
    C = _capacity(T, cfg, decode)
    gate = p.get("gate")

    use_ep = (
        mesh is not None
        and "model" in mesh.axis_names
        and cfg.moe_impl in ("auto", "ep")
        and cfg.n_experts % mesh.shape["model"] == 0
    )
    if use_ep:
        n_model = mesh.shape["model"]
        n_local = cfg.n_experts // n_model
        batch_axes = tuple(a for a in BATCH_AXES if a in mesh.axis_names)

        def local_fn(xf, tw, ti, up, gt, dn):
            j = jax.lax.axis_index("model")
            c_loc = _capacity(xf.shape[0], cfg, decode)
            y = _expert_compute(xf, tw, ti, up,
                                gt if gate is not None else None, dn,
                                expert_offset=j * n_local, n_local=n_local,
                                capacity=c_loc, cfg=cfg)
            return jax.lax.psum(y, "model")

        tok = P(batch_axes if batch_axes else None, None)
        espec = P("model", None, None)
        gate_arg = gate if gate is not None else p["up"]  # placeholder, unused
        y_flat = jax.shard_map(
            local_fn, mesh=mesh,
            in_specs=(tok, tok, tok, espec, espec, espec),
            out_specs=tok,
        )(x_flat, top_w, top_i, p["up"], gate_arg, p["down"])
    else:
        # local capacity should reflect the *local* token count
        y_flat = _expert_compute(x_flat, top_w, top_i, p["up"], gate,
                                 p["down"], expert_offset=0,
                                 n_local=cfg.n_experts, capacity=C, cfg=cfg)

    y = y_flat.reshape(B, S, d)
    if "shared" in p:
        y = y + nn.mlp_apply(p["shared"], x, activation=cfg.activation,
                             compute_dtype=cfg.cdtype)
    return y.astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Dropless reference (tests only; loops over experts)
# ---------------------------------------------------------------------------


def moe_reference(p, x, cfg: ModelConfig):
    B, S, d = x.shape
    x_flat = x.reshape(-1, d)
    top_w, top_i, aux = route(p["router"]["w"], x_flat, cfg)
    act = nn.ACTIVATIONS[cfg.activation]
    y = jnp.zeros_like(x_flat, jnp.float32)
    for e in range(cfg.n_experts):
        w_e = jnp.where(top_i == e, top_w, 0.0).sum(-1)  # [T]
        up = x_flat @ p["up"][e]
        if "gate" in p:
            h = act(x_flat @ p["gate"][e]) * up
        else:
            h = act(up)
        out = h @ p["down"][e]
        y = y + out.astype(jnp.float32) * w_e[:, None]
    y = y.reshape(B, S, d)
    if "shared" in p:
        y = y + nn.mlp_apply(p["shared"], x, activation=cfg.activation)
    return y.astype(x.dtype), aux
