"""Mamba2 (SSD) blocks and the Zamba2 hybrid model.

Training path uses the chunked SSD algorithm (quadratic only within a chunk,
linear across chunks via a small ``lax.scan``); all decay exponents are
differences of a *decreasing* cumulative log-decay, hence <= 0 and numerically
safe.  Decode is a single-step recurrence carrying ``[B,H,N,P]`` SSM state +
a ``[B,W-1,conv_dim]`` conv tail — O(1) per token, which is what makes
``long_500k`` runnable for the hybrid/ssm archs.

Zamba2 wiring: groups of ``attn_every`` Mamba2 blocks followed by one *shared*
full-attention transformer block (one weight copy reused at every application,
the Zamba trick).  Implemented as an outer scan over groups (stacked params)
with the shared block closed over as a scan constant.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from . import nn
from . import transformer as tfm
from .config import ModelConfig


# ---------------------------------------------------------------------------
# Dims helper
# ---------------------------------------------------------------------------


def ssm_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_dim = d_in + 2 * N
    return d_in, H, N, conv_dim


# ---------------------------------------------------------------------------
# Block params
# ---------------------------------------------------------------------------


def mamba_block_init(key, cfg: ModelConfig):
    """Projections are split (z / x / B / C / dt + per-stream convs) so every
    tensor-parallel dim is a clean logical axis — no slicing of sharded dims.
    Mathematically identical to the fused in_proj/conv of the reference impl.
    """
    d = cfg.d_model
    d_in, H, N, conv_dim = ssm_dims(cfg)
    W = cfg.ssm_conv
    dt = cfg.pdtype
    ks = jax.random.split(key, 10)
    # dt bias: inverse softplus of dt ~ U[1e-3, 1e-1]
    u = jax.random.uniform(ks[2], (H,), minval=math.log(1e-3),
                           maxval=math.log(1e-1))
    dt0 = jnp.exp(u)
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))
    return {
        "ln": nn.rmsnorm_init(d, dtype=dt),
        "in_z": nn.linear_init(ks[0], d, d_in, axes=("embed", "ssm_inner"),
                               dtype=dt),
        "in_x": nn.linear_init(ks[1], d, d_in, axes=("embed", "ssm_inner"),
                               dtype=dt),
        "in_B": nn.linear_init(ks[5], d, N, axes=("embed", "ssm_state"),
                               dtype=dt),
        "in_C": nn.linear_init(ks[6], d, N, axes=("embed", "ssm_state"),
                               dtype=dt),
        "in_dt": nn.linear_init(ks[7], d, H, axes=("embed", "ssm_heads"),
                                dtype=dt),
        "conv_x": nn.Px(nn.normal_init(ks[1], (W, d_in), dt,
                                       1.0 / math.sqrt(W)),
                        ("conv_w", "ssm_inner")),
        "conv_x_b": nn.Px(jnp.zeros((d_in,), dt), ("ssm_inner",)),
        "conv_B": nn.Px(nn.normal_init(ks[8], (W, N), dt,
                                       1.0 / math.sqrt(W)),
                        ("conv_w", "ssm_state")),
        "conv_B_b": nn.Px(jnp.zeros((N,), dt), ("ssm_state",)),
        "conv_C": nn.Px(nn.normal_init(ks[9], (W, N), dt,
                                       1.0 / math.sqrt(W)),
                        ("conv_w", "ssm_state")),
        "conv_C_b": nn.Px(jnp.zeros((N,), dt), ("ssm_state",)),
        "A_log": nn.Px(jnp.log(jax.random.uniform(
            ks[3], (H,), minval=1.0, maxval=16.0)).astype(jnp.float32),
            ("ssm_heads",)),
        "D": nn.Px(jnp.ones((H,), jnp.float32), ("ssm_heads",)),
        "dt_bias": nn.Px(dt_bias.astype(jnp.float32), ("ssm_heads",)),
        "norm": nn.rmsnorm_init(d_in, axis="ssm_inner", dtype=dt),
        "out_proj": nn.linear_init(ks[4], d_in, d,
                                   axes=("ssm_inner", "embed"), dtype=dt),
    }


# ---------------------------------------------------------------------------
# Causal depthwise conv
# ---------------------------------------------------------------------------


def causal_conv(x, w, b, *, tail=None):
    """x [B,T,C]; w [W,C]; optional tail [B,W-1,C] from previous tokens.

    Returns (y [B,T,C], new_tail [B,W-1,C]).
    """
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)  # [B, T+W-1, C]
    y = sum(
        xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(W)
    )
    y = jax.nn.silu(y + b[None, None, :])
    new_tail = xp[:, -(W - 1):, :] if W > 1 else tail
    return y, new_tail


# ---------------------------------------------------------------------------
# SSD (chunked + recurrent)
# ---------------------------------------------------------------------------


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, h0=None):
    """Chunked SSD.

    x [B,T,H,P]; dt [B,T,H]; A [H] (negative); Bm/Cm [B,T,N].
    Returns (y [B,T,H,P], h_final [B,H,N,P]).
    """
    B_, T, H, P = x.shape
    N = Bm.shape[-1]
    L = min(chunk, T)
    if T % L:
        raise ValueError(f"T={T} not divisible by chunk={L}")
    nc = T // L
    f32 = jnp.float32

    a = (dt.astype(f32) * A.astype(f32)[None, None, :])  # [B,T,H] <= 0
    xc = x.reshape(B_, nc, L, H, P)
    dtc = dt.reshape(B_, nc, L, H).astype(f32)
    ac = a.reshape(B_, nc, L, H)
    Bc = Bm.reshape(B_, nc, L, N).astype(f32)
    Cc = Cm.reshape(B_, nc, L, N).astype(f32)
    cum = jnp.cumsum(ac, axis=2)  # inclusive, decreasing

    # ---- intra-chunk (quadratic within chunk) ----
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # [B,nc,L,L]
    delta = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,i,j,H]
    mask = jnp.tril(jnp.ones((L, L), bool))
    dec = jnp.where(mask[None, None, :, :, None], jnp.exp(delta), 0.0)
    scores = CB[..., None] * dec * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores.astype(x.dtype), xc)

    # ---- chunk-boundary states ----
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # [B,nc,L,H] <= 1
    Sc = jnp.einsum("bcjh,bcjn,bcjhp->bchnp",
                    (decay_to_end * dtc).astype(x.dtype), Bc.astype(x.dtype),
                    xc)  # [B,nc,H,N,P]

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # [B,nc,H]
    h_init = (jnp.zeros((B_, H, N, P), x.dtype) if h0 is None
              else h0.astype(x.dtype))

    def scan_f(h, inp):
        cd, s = inp  # cd [B,H], s [B,H,N,P]
        h_new = cd[:, :, None, None].astype(h.dtype) * h + s
        return h_new, h  # emit previous-chunk state

    h_final, h_prevs = jax.lax.scan(
        scan_f, h_init,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(Sc, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)  # [B,nc,H,N,P]

    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", Cc.astype(x.dtype),
                         jnp.exp(cum).astype(x.dtype), h_prevs)
    y = (y_intra + y_inter).reshape(B_, T, H, P)
    return y, h_final


def ssd_recurrent(x, dt, A, Bm, Cm, h0=None):
    """Step-by-step oracle; same signature/returns as ssd_chunked."""
    B_, T, H, P = x.shape
    N = Bm.shape[-1]
    h = jnp.zeros((B_, H, N, P), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        h, y = ssd_step(h, x_t, dt_t, A, B_t, C_t)
        return h, y

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Bm, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Cm, 1, 0).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h


def ssd_step(h, x_t, dt_t, A, B_t, C_t):
    """h [B,H,N,P]; x_t [B,H,P]; dt_t [B,H]; B_t/C_t [B,N]."""
    da = jnp.exp(dt_t.astype(jnp.float32) * A.astype(jnp.float32))  # [B,H]
    upd = jnp.einsum("bh,bn,bhp->bhnp", dt_t.astype(jnp.float32),
                     B_t.astype(jnp.float32), x_t.astype(jnp.float32))
    h = da[:, :, None, None] * h.astype(jnp.float32) + upd
    y = jnp.einsum("bn,bhnp->bhp", C_t.astype(jnp.float32), h)
    return h, y


# ---------------------------------------------------------------------------
# Block apply
# ---------------------------------------------------------------------------


def _project_streams(p, u, cfg, state):
    """Shared projection + conv path for train/prefill/decode."""
    cd = cfg.cdtype
    z = nn.linear_apply(p["in_z"], u, cd)
    x = nn.linear_apply(p["in_x"], u, cd)
    Bm = nn.linear_apply(p["in_B"], u, cd)
    Cm = nn.linear_apply(p["in_C"], u, cd)
    dt = nn.linear_apply(p["in_dt"], u, cd)
    tails = state["conv"] if state is not None else {"x": None, "B": None,
                                                     "C": None}
    x, tx = causal_conv(x, p["conv_x"].astype(x.dtype),
                        p["conv_x_b"].astype(x.dtype), tail=tails["x"])
    Bm, tb = causal_conv(Bm, p["conv_B"].astype(x.dtype),
                         p["conv_B_b"].astype(x.dtype), tail=tails["B"])
    Cm, tc = causal_conv(Cm, p["conv_C"].astype(x.dtype),
                         p["conv_C_b"].astype(x.dtype), tail=tails["C"])
    new_tails = {"x": tx, "B": tb, "C": tc}
    return z, x, Bm, Cm, dt, new_tails


def mamba_block_apply(p, u, cfg: ModelConfig, *, state=None,
                      return_state: bool = False, recurrent_oracle=False):
    """Full-sequence Mamba2 block. u [B,T,d].

    state (optional): {"conv": {x,B,C tails}, "ssm": [B,H,N,P]}.
    Returns y or (y, new_state).
    """
    d_in, H, N, conv_dim = ssm_dims(cfg)
    P_ = cfg.ssm_head_dim
    B_, T, _ = u.shape
    x_res = u
    u = nn.rmsnorm_apply(p["ln"], u, cfg.norm_eps)
    z, x, Bm, Cm, dt, new_tails = _project_streams(p, u, cfg, state)
    x = x.reshape(B_, T, H, P_)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    h0 = state["ssm"] if state is not None else None
    if recurrent_oracle:
        y, h = ssd_recurrent(x, dt, A, Bm, Cm, h0=h0)
    else:
        y, h = ssd_chunked(x, dt, A, Bm, Cm, cfg.ssm_chunk, h0=h0)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * x
    y = y.reshape(B_, T, d_in)
    y = nn.rmsnorm_apply(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = x_res + nn.linear_apply(p["out_proj"], y, cfg.cdtype)
    if return_state:
        return out, {"conv": new_tails, "ssm": h}
    return out


def mamba_block_step(p, u, state, cfg: ModelConfig):
    """Single-token decode. u [B,1,d]. Returns (y [B,1,d], new_state)."""
    d_in, H, N, conv_dim = ssm_dims(cfg)
    P_ = cfg.ssm_head_dim
    B_ = u.shape[0]
    x_res = u
    u = nn.rmsnorm_apply(p["ln"], u, cfg.norm_eps)
    z, x, Bm, Cm, dt, new_tails = _project_streams(p, u, cfg, state)
    x = x[:, 0].reshape(B_, H, P_)
    dt_t = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["A_log"])
    h, y = ssd_step(state["ssm"], x, dt_t, A, Bm[:, 0], Cm[:, 0])
    y = y + p["D"].astype(y.dtype)[None, :, None] * x
    y = y.reshape(B_, 1, d_in).astype(z.dtype)
    y = nn.rmsnorm_apply(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = x_res + nn.linear_apply(p["out_proj"], y, cfg.cdtype)
    return out, {"conv": new_tails, "ssm": h}


def init_ssm_state(cfg: ModelConfig, batch: int):
    d_in, H, N, conv_dim = ssm_dims(cfg)
    W = cfg.ssm_conv
    return {
        "conv": {
            "x": jnp.zeros((batch, W - 1, d_in), cfg.cdtype),
            "B": jnp.zeros((batch, W - 1, N), cfg.cdtype),
            "C": jnp.zeros((batch, W - 1, N), cfg.cdtype),
        },
        "ssm": jnp.zeros((batch, H, N, cfg.ssm_head_dim), jnp.float32),
    }


# ---------------------------------------------------------------------------
# Zamba2 hybrid model (groups of mamba blocks + one shared attention block)
# ---------------------------------------------------------------------------


def hybrid_init(key, cfg: ModelConfig):
    if cfg.attn_every <= 0 or cfg.n_layers % cfg.attn_every:
        raise ValueError("hybrid needs n_layers % attn_every == 0")
    G = cfg.n_layers // cfg.attn_every
    K = cfg.attn_every
    ks = jax.random.split(key, 5)
    dt = cfg.pdtype
    layer_keys = jax.random.split(ks[1], G * K)
    groups = [
        nn.stack_layers([mamba_block_init(layer_keys[g * K + i], cfg)
                         for i in range(K)])
        for g in range(G)
    ]
    p = {
        "embed": nn.embedding_init(ks[0], cfg.vocab, cfg.d_model, dtype=dt),
        "groups": nn.stack_layers(groups),  # leading axes [G, K, ...]
        "shared": tfm.block_init(ks[2], cfg, layer_idx=0),
        "ln_f": nn.rmsnorm_init(cfg.d_model, dtype=dt),
        "unembed": nn.linear_init(ks[3], cfg.d_model, cfg.vocab,
                                  axes=("embed", "vocab"), dtype=dt),
    }
    return p


def hybrid_forward(p, batch, cfg: ModelConfig, *, mesh=None):
    tokens = batch["tokens"]
    x = nn.embedding_apply(p["embed"], tokens, cfg.cdtype, mesh=mesh)
    T = x.shape[1]
    positions = jnp.arange(T)[None, :]
    shared = p["shared"]
    aspec = nn.batch_pspec(mesh, x.shape[0])
    x = nn.constrain(x, mesh, aspec)

    def group_body(x, group_params):
        def inner(x, bp):
            x = nn.constrain(x, mesh, aspec)
            return nn.constrain(mamba_block_apply(bp, x, cfg), mesh,
                                aspec), None

        x, _ = jax.lax.scan(inner, x, group_params)
        y, _ = tfm.block_apply(shared, x, cfg, causal=True,
                               positions=positions, mesh=mesh)
        return nn.constrain(y, mesh, aspec), None

    x, _ = jax.lax.scan(tfm.remat_wrap(group_body, cfg), x, p["groups"])
    x = nn.rmsnorm_apply(p["ln_f"], x, cfg.norm_eps)
    logits = nn.linear_apply(p["unembed"], x, jnp.float32)
    if mesh is not None:
        from jax.sharding import PartitionSpec as P

        logits = nn.constrain(
            logits, mesh,
            P(aspec[0], None, "model" if "model" in mesh.axis_names else None))
    return logits, jnp.zeros((), jnp.float32)


def hybrid_loss(p, batch, cfg: ModelConfig, *, mesh=None):
    logits, aux = hybrid_forward(p, batch, cfg, mesh=mesh)
    return tfm._ce_from_logits(logits, batch, aux, cfg, mesh=mesh)


def hybrid_prefill(p, batch, cfg: ModelConfig, *, max_len: int, mesh=None):
    tokens = batch["tokens"]
    B_, S = tokens.shape
    x = nn.embedding_apply(p["embed"], tokens, cfg.cdtype, mesh=mesh)
    positions = jnp.arange(S)[None, :]
    shared = p["shared"]

    def group_body(x, group_params):
        def inner(x, bp):
            y, st = mamba_block_apply(bp, x, cfg, return_state=True)
            return y, st

        x, states = jax.lax.scan(inner, x, group_params)
        y, cache = tfm.block_prefill(shared, x, cfg, max_len=max_len,
                                     positions=positions, mesh=mesh)
        return y, (states, cache)

    x, (ssm_states, attn_caches) = jax.lax.scan(group_body, x, p["groups"])
    x = nn.rmsnorm_apply(p["ln_f"], x, cfg.norm_eps)
    logits = nn.linear_apply(p["unembed"], x[:, -1:, :], jnp.float32)[:, 0]
    cache = {"ssm": ssm_states, "attn": attn_caches}
    return cache, logits


def hybrid_decode_step(p, cache, tokens, cfg: ModelConfig, *, mesh=None):
    x = nn.embedding_apply(p["embed"], tokens[:, None], cfg.cdtype, mesh=mesh)
    shared = p["shared"]

    def group_body(x, inp):
        group_params, states, attn_cache = inp

        def inner(x, bp_st):
            bp, st = bp_st
            y, st2 = mamba_block_step(bp, x, st, cfg)
            return y, st2

        x, new_states = jax.lax.scan(inner, x, (group_params, states))
        y, new_attn = tfm.block_decode(shared, x, attn_cache, cfg, mesh=mesh)
        return y, (new_states, new_attn)

    x, (new_ssm, new_attn) = jax.lax.scan(
        group_body, x, (p["groups"], cache["ssm"], cache["attn"]))
    x = nn.rmsnorm_apply(p["ln_f"], x, cfg.norm_eps)
    logits = nn.linear_apply(p["unembed"], x, jnp.float32)[:, 0]
    return {"ssm": new_ssm, "attn": new_attn}, logits
