"""Model configuration shared by every assigned architecture."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "float16": jnp.float16,
}


@dataclasses.dataclass
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | hybrid | ssm | encdec | vlm

    # Core transformer dims
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 256
    vocab: int = 256
    head_dim: int = 0  # 0 -> d_model // n_heads

    # Attention
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    attention_impl: str = "auto"  # auto | full | chunked | pallas
    attn_chunk_q: int = 1024
    attn_chunk_k: int = 1024
    positions: str = "rope"  # rope | learned | sinusoidal | none

    # MLP
    activation: str = "silu"
    gated_mlp: bool = True

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    first_dense_layers: int = 0  # deepseek-style: first N layers use dense FFN
    dense_ff: int = 0  # d_ff of the dense layers (0 -> n_experts * d_ff heuristics)
    capacity_factor: float = 1.25
    decode_capacity_factor: float = 4.0  # decode batches are small; drops hurt
    moe_impl: str = "auto"  # auto | dense | ep (shard_map + ragged_dot)
    router_aux_weight: float = 0.01

    # SSM (mamba2) / hybrid (zamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    attn_every: int = 0  # hybrid: shared attention block after every N ssm blocks

    # RWKV6
    rwkv_head_dim: int = 64
    rwkv_lora_decay: int = 64
    rwkv_lora_mix: int = 32
    rwkv_chunk: int = 32

    # Encoder-decoder (whisper)
    enc_layers: int = 0
    dec_layers: int = 0
    cross_attention: bool = False

    # VLM
    vision_tokens: int = 0

    # Embedding / sequence
    tie_embeddings: bool = False
    max_seq: int = 4096
    norm_eps: float = 1e-6
    final_logit_softcap: float = 0.0

    # Compute / memory policy
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    scan_layers: bool = True
    remat: str = "full"  # none | full | dots
    use_pallas: bool = False  # TPU target; CPU tests use interpret/jnp paths
    # distribution optimizations (hillclimb; baseline = False)
    pad_heads_to: int = 0  # pad q-heads per kv-group for clean TP sharding
    explicit_tp: bool = False  # Megatron-style shard_map TP linears (bf16 AR)
    fsdp_params: bool = False  # explicit bf16 FSDP gathers inside TP linears
    seq_shard_activations: bool = False  # Megatron-SP residual sharding

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            self.head_dim = self.d_model // self.n_heads
        if self.family == "encdec" and self.enc_layers == 0:
            self.enc_layers = self.n_layers
            self.dec_layers = self.n_layers
            self.cross_attention = True

    # -- dtype helpers ------------------------------------------------------
    @property
    def pdtype(self):
        return DTYPES[self.param_dtype]

    @property
    def cdtype(self):
        return DTYPES[self.compute_dtype]

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(1, self.n_kv_heads)

    @property
    def padded_heads(self) -> int:
        """Effective q-head count incl. TP padding (zero-output heads)."""
        return self.pad_heads_to or self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def decode_state_kind(self) -> str:
        """What per-request state decoding carries."""
        if self.family == "ssm":
            return "recurrent"
        if self.family == "hybrid":
            return "mixed"  # ssm state + (small) attention KV for shared blocks
        return "kv"

    def scaled(self, **overrides) -> "ModelConfig":
        return dataclasses.replace(self, **overrides)

    # -- analytical param count (for roofline MODEL_FLOPS) -------------------
    def param_count_analytical(self) -> int:
        """Rough analytical parameter count (embedding + blocks)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hd, nh, nkv = self.head_dim, self.n_heads, self.n_kv_heads
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
        mlp_dense = d * ff * (3 if self.gated_mlp else 2)
        if self.family == "ssm":  # rwkv6
            att = 4 * d * d + d * d  # r,k,v,g,o approx
            ffn = 2 * d * ff
            return emb + self.n_layers * (att + ffn)
        if self.family == "hybrid":
            d_in = self.ssm_expand * d
            ssm = d * (2 * d_in + 2 * self.ssm_state) + d_in * d
            n_attn = self.n_layers // max(1, self.attn_every)
            shared = attn + mlp_dense  # one shared block, reused
            return emb + self.n_layers * ssm + shared
        if self.is_moe:
            expert = d * ff * (3 if self.gated_mlp else 2)
            moe_layers = self.n_layers - self.first_dense_layers
            router = d * self.n_experts
            total = emb + self.n_layers * attn
            total += moe_layers * (
                (self.n_experts + self.n_shared_experts) * expert + router
            )
            dense_ff = self.dense_ff or ff
            total += self.first_dense_layers * d * dense_ff * (3 if self.gated_mlp else 2)
            return total
        n_blocks = (
            self.enc_layers + self.dec_layers
            if self.family == "encdec"
            else self.n_layers
        )
        cross = attn if self.cross_attention else 0
        return emb + n_blocks * (attn + mlp_dense + cross)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if not self.is_moe:
            return self.param_count_analytical()
        d, ff = self.d_model, self.d_ff
        expert = d * ff * (3 if self.gated_mlp else 2)
        total = self.param_count_analytical()
        moe_layers = self.n_layers - self.first_dense_layers
        inactive = moe_layers * (self.n_experts - self.top_k) * expert
        return total - inactive
