"""Pallas kernel micro-benchmarks (interpret mode on CPU).

Wall times here are CPU-interpret times (correctness artifacts, NOT TPU
perf); the derived column reports the kernel's work so the TPU roofline can
be cross-checked: flops, bytes, and the arithmetic intensity the BlockSpec
tiling achieves.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .common import Reporter


def _time(fn, *args, n=3):
    fn(*args).block_until_ready() if hasattr(fn(*args), "block_until_ready") \
        else None
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def main(rep: Reporter) -> dict:
    key = jax.random.PRNGKey(0)
    out = {}

    # flash attention
    from repro.kernels.flash_attention.ops import flash_attention

    B, S, H, D = 1, 256, 4, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    dt = _time(lambda a, b, c: flash_attention(
        a, b, c, causal=True, block_q=128, block_k=128, interpret=True), q, k, v)
    flops = 2 * B * H * (S * S // 2) * D * 2
    rep.add("kernel_flash_attention", dt * 1e6,
            f"S={S} D={D} causal flops={flops:.2e} (interpret)")
    out["flash"] = dt

    # decode attention
    from repro.kernels.decode_attention.ops import decode_attention

    B2, S2, Hq, Hkv = 4, 512, 8, 2
    q2 = jax.random.normal(ks[0], (B2, 1, Hq, D))
    kc = jax.random.normal(ks[1], (B2, S2, Hkv, D))
    vc = jax.random.normal(ks[2], (B2, S2, Hkv, D))
    lens = jnp.full((B2,), S2, jnp.int32)
    dt = _time(lambda a, b, c: decode_attention(
        a, b, c, lens, block_k=256, interpret=True), q2, kc, vc)
    bytes_moved = 2 * B2 * S2 * Hkv * D * 4
    rep.add("kernel_decode_attention", dt * 1e6,
            f"S={S2} G={Hq // Hkv} bytes={bytes_moved:.2e} AI~{Hq // Hkv}")
    out["decode"] = dt

    # rwkv6 wkv
    from repro.kernels.rwkv6.ops import wkv

    B3, T3, H3, hd = 1, 128, 4, 32
    ks2 = jax.random.split(key, 5)
    r = jax.random.normal(ks2[0], (B3, T3, H3, hd))
    k3 = jax.random.normal(ks2[1], (B3, T3, H3, hd))
    v3 = jax.random.normal(ks2[2], (B3, T3, H3, hd))
    lw = -jnp.exp(jax.random.normal(ks2[3], (B3, T3, H3, hd)) - 1.0)
    u = jax.random.normal(ks2[4], (H3, hd)) * 0.1
    dt = _time(lambda a, b, c: wkv(a, b, c, lw, u, chunk=32, interpret=True),
               r, k3, v3)
    rep.add("kernel_rwkv6_wkv", dt * 1e6, f"T={T3} hd={hd} chunk=32")
    out["wkv"] = dt

    # mamba2 ssd
    from repro.kernels.mamba2.ops import ssd

    B4, T4, H4, P4, N4 = 1, 128, 4, 16, 8
    x = jax.random.normal(ks2[0], (B4, T4, H4, P4))
    dts = jax.nn.softplus(jax.random.normal(ks2[1], (B4, T4, H4)))
    A = -jnp.exp(jax.random.normal(ks2[2], (H4,)))
    Bm = jax.random.normal(ks2[3], (B4, T4, N4))
    Cm = jax.random.normal(ks2[4], (B4, T4, N4))
    dt = _time(lambda a: ssd(a, dts, A, Bm, Cm, chunk=32, interpret=True), x)
    rep.add("kernel_mamba2_ssd", dt * 1e6, f"T={T4} N={N4} chunk=32")
    out["ssd"] = dt
    return out


if __name__ == "__main__":
    main(Reporter())
