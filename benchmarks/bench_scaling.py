"""Experiment 1 (Fig. 3): baseline runtime performance with no-op tasks.

Weak scaling: tasks grow with worker count (constant work per worker).
Strong scaling: fixed task count, growing worker count.
Metrics: throughput (tasks/s) and runtime overhead (s; us/task) — the paper
reports ~100-300 us/task for RHAPSODY+Dragon.
"""
from __future__ import annotations

import time

from repro.core import Rhapsody, ResourceDescription, TaskDescription
from repro.substrate.simulation import noop

from .common import Reporter


def run_batch(n_tasks: int, n_workers: int) -> dict:
    rh = Rhapsody(ResourceDescription(nodes=n_workers, cores_per_node=64),
                  n_workers=n_workers)
    try:
        descs = [TaskDescription(fn=noop, task_type="noop")
                 for _ in range(n_tasks)]
        t0 = time.perf_counter()
        uids = rh.submit(descs)
        rh.wait(uids)
        dt = time.perf_counter() - t0
        return {
            "tasks": n_tasks,
            "workers": n_workers,
            "seconds": dt,
            "tasks_per_s": n_tasks / dt,
            "us_per_task": dt / n_tasks * 1e6,
        }
    finally:
        rh.close()


def main(rep: Reporter, *, weak_per_worker: int = 2048,
         strong_total: int = 8192, worker_counts=(1, 2, 4, 8)) -> dict:
    weak, strong = [], []
    for w in worker_counts:
        r = run_batch(weak_per_worker * w, w)
        weak.append(r)
        rep.add(f"exp1_weak_w{w}", r["us_per_task"],
                f"{r['tasks_per_s']:.0f} tasks/s n={r['tasks']}")
    for w in worker_counts:
        r = run_batch(strong_total, w)
        strong.append(r)
        rep.add(f"exp1_strong_w{w}", r["us_per_task"],
                f"{r['tasks_per_s']:.0f} tasks/s n={r['tasks']}")
    return {"weak": weak, "strong": strong}


if __name__ == "__main__":
    main(Reporter())
