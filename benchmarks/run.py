"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (+ JSON artifacts under
results/).  Experiments map 1:1 to the paper:

  exp1_*      Fig. 3  scaling of no-op task dispatch (weak/strong)
  exp2_*      Fig. 4  heterogeneity width
  exp3_*      Fig. 5a,b inference-at-scale throughput/utilization
  exp4_*      Fig. 5c,d batching sensitivity + routing policies
  exp5_*      Fig. 6  coupled AI-HPC data exchange
  exp6_*      Fig. 7  agent decision rate vs ARR
  roofline_*  (this build) dry-run roofline terms per arch x shape
  kernel_*    Pallas kernel micro-benchmarks (interpret mode on CPU)
"""
from __future__ import annotations

import argparse
import sys

from . import (bench_agentic, bench_coupling, bench_heterogeneity,
               bench_inference_scaling, bench_roofline, bench_routing,
               bench_scaling)
from .common import Reporter


def _kernels(rep):
    from . import bench_kernels

    return bench_kernels.main(rep)


SUITES = {
    "exp1_scaling": lambda rep: bench_scaling.main(rep),
    "exp2_heterogeneity": lambda rep: bench_heterogeneity.main(rep),
    "exp3_inference": lambda rep: bench_inference_scaling.main(rep),
    "exp4_routing": lambda rep: bench_routing.main(rep),
    "exp5_coupling": lambda rep: bench_coupling.main(rep),
    "exp6_agentic": lambda rep: bench_agentic.main(rep),
    "roofline": lambda rep: bench_roofline.main(rep),
    "kernels": _kernels,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of suites to run")
    args = ap.parse_args()
    rep = Reporter()
    print("name,us_per_call,derived")
    payload = {}
    failures = []
    for name, fn in SUITES.items():
        if args.only and name not in args.only:
            continue
        try:
            payload[name] = fn(rep)
        except Exception as e:  # noqa: BLE001 — keep the suite running
            failures.append((name, repr(e)))
            rep.add(f"{name}_FAILED", 0.0, repr(e)[:120])
    rep.save_json("benchmarks.json", payload)
    if failures:
        print(f"# {len(failures)} suite(s) failed", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
