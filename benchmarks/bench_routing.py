"""Experiment 4 (Fig. 5c,d): batching-parameter sensitivity + routing policy.

(c) throughput vs ``max_num_seqs`` x ``max_num_batched_tokens`` on a fixed
prompt subset — the paper finds max_num_seqs dominates.
(d) strong scaling of a fixed heterogeneous prompt set (lognormal lengths,
the 4k-50k-token LUCID analogue scaled down) across 1-4 replicas of ONE
service under randomized vs token-aware balanced routing, all dispatched
through the middleware router (INFERENCE tasks, not pinned endpoints).

CLI replica sweep (synthetic servicer, isolates routing + replication from
model compute)::

    PYTHONPATH=src python -m benchmarks.bench_routing --replicas 1 2 4

reports aggregate and per-replica throughput plus p50/p95/p99 latency per
replica count — the Fig 5d shape: near-linear aggregate scaling.

Affinity sweep (``--affinity``): sessioned multi-turn request streams
(each session's prompt grows turn over turn, the chat pattern) against a
synthetic servicer whose cost covers only the prompt tokens its replica
has NOT already served — the KV-reuse cost model, radix-accurate: a
replica that served a *diverging* sibling prompt still covers the shared
stem (partial prefix resume).  Compares ``radix_affinity`` (longest-
prefix-match + prefix-aware spill) vs ``prefix_affinity`` (PR 2's
hashed-LRU baseline) vs ``least_loaded`` across replica counts on three
streams:

  * ``sessioned`` — per-session unique prefixes, monotonically growing
    prompts (hit rate + throughput win for both sticky policies);
  * ``branching`` — the agentic-campaign pattern (paper §Fig. 7): every
    agent shares one system-prompt stem LONGER than the hashed affinity
    window, then diverges with its own turns.  The hash maps all agents
    to a single key, so hashed-LRU cannot tell sessions apart; radix
    longest-match still homes each agent on its warmest replica;
  * ``uniform`` — unrelated prompts (no-regression check)::

    PYTHONPATH=src python -m benchmarks.bench_routing --affinity --replicas 1 2 4

``--json`` emits the rows as a JSON array (CI smoke parses it).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.configs import get_config
from repro.core import (ExecutionPolicy, ResourceDescription, Rhapsody,
                        ServiceDescription, TaskDescription, TaskKind)
from repro.core.prefix import RadixIndex
from repro.core.router import ROUTERS
from repro.serving.client import llm_service_factory

from .common import Reporter


def engine_cfg():
    return get_config("rhapsody-demo").scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512)


def hetero_prompts(n: int, seed: int = 0, lo: int = 8, hi: int = 96):
    rng = np.random.RandomState(seed)
    lens = np.clip(np.exp(rng.normal(3.0, 0.8, size=n)).astype(int), lo, hi)
    return [list(rng.randint(0, 512, size=int(L))) for L in lens]


# ---------------------------------------------------------------------------
# (c) batching parameter sensitivity
# ---------------------------------------------------------------------------


def sweep_batching(rep: Reporter, *, n_prompts: int = 24) -> list:
    cfg = engine_cfg()
    prompts = hetero_prompts(n_prompts, seed=1)
    out = []
    for max_num_seqs in (2, 4, 8):
        for max_tokens in (128, 512):
            rh = Rhapsody(ResourceDescription(nodes=1, cores_per_node=8),
                          n_workers=1)
            try:
                ep = rh.add_service(ServiceDescription(
                    name="llm", factory=llm_service_factory(
                        cfg, max_num_seqs=max_num_seqs,
                        max_num_batched_tokens=max_tokens,
                        max_len=128, prefill_buckets=(32, 64, 128))))
                t0 = time.perf_counter()
                futs = [ep.request({"prompt": p, "max_new_tokens": 8})
                        for p in prompts]
                res = [f.result(timeout=600) for f in futs]
                dt = time.perf_counter() - t0
                tokens = sum(len(r["tokens"]) + r["n_prompt"] for r in res)
                row = {"max_num_seqs": max_num_seqs,
                       "max_num_batched_tokens": max_tokens,
                       "tokens_per_s": tokens / dt, "seconds": dt}
                out.append(row)
                rep.add(f"exp4_batch_s{max_num_seqs}_t{max_tokens}",
                        dt * 1e6 / n_prompts,
                        f"{row['tokens_per_s']:.0f} tok/s")
            finally:
                rh.close()
    return out


# ---------------------------------------------------------------------------
# (d) routing policy strong scaling — one replicated service, middleware
#     router on the dispatch path
# ---------------------------------------------------------------------------


def routed_run(n_replicas: int, policy: str, prompts) -> dict:
    cfg = engine_cfg()
    rh = Rhapsody(ResourceDescription(nodes=1,
                                      cores_per_node=max(8, len(prompts))),
                  policy=ExecutionPolicy(routing=policy),
                  n_workers=1)
    try:
        replica_set = rh.add_service(ServiceDescription(
            name="llm", replicas=n_replicas,
            factory=llm_service_factory(
                cfg, max_num_seqs=4, max_len=128,
                prefill_buckets=(32, 64, 128))))
        descs = [TaskDescription(kind=TaskKind.INFERENCE, service="llm",
                                 payload={"prompt": p, "max_new_tokens": 8},
                                 task_type="inference")
                 for p in prompts]
        t0 = time.perf_counter()
        uids = rh.submit(descs)
        if not rh.wait(uids, timeout=600):
            raise TimeoutError("inference stream timed out")
        dt = time.perf_counter() - t0
        results = [rh.result(u) for u in uids]
        tokens = sum(len(r["tokens"]) + r["n_prompt"] for r in results)
        stats = replica_set.stats()
        per = [p["requests"] for p in stats["per_replica"]]
        # Fig 5d compares TOKEN-load spread (balanced routing equalizes
        # cost, not request count — one huge prompt offsets many small)
        loads = [p["cost"] for p in stats["per_replica"]]
        return {"replicas": n_replicas, "policy": policy, "seconds": dt,
                "tokens_per_s": tokens / dt,
                "per_replica_requests": per,
                "load_imbalance": max(loads) / max(1.0, min(loads))}
    finally:
        rh.close()


def main(rep: Reporter, *, n_prompts: int = 24,
         service_counts=(1, 2, 4)) -> dict:
    sens = sweep_batching(rep, n_prompts=min(12, n_prompts))
    prompts = hetero_prompts(n_prompts, seed=2)
    scaling = []
    for n in service_counts:
        for policy in ("random", "balanced"):
            r = routed_run(n, policy, prompts)
            scaling.append(r)
            rep.add(f"exp4_route_{policy}_s{n}",
                    r["seconds"] * 1e6 / n_prompts,
                    f"{r['tokens_per_s']:.0f} tok/s "
                    f"imbalance={r['load_imbalance']:.2f}")
    return {"sensitivity": sens, "scaling": scaling}


# ---------------------------------------------------------------------------
# Replica scaling sweep with a synthetic servicer (Fig 5d shape without
# model compute): aggregate + per-replica throughput, tail latency
# ---------------------------------------------------------------------------


class SyntheticServicer:
    """Sync servicer that burns wall time proportional to prompt tokens —
    each replica is one serial worker, so N replicas ≈ N-way parallelism."""

    def __init__(self, base_ms: float = 2.0, us_per_token: float = 30.0):
        self.base_ms = base_ms
        self.us_per_token = us_per_token

    def handle(self, payload):
        n = len(payload.get("prompt", ()))
        time.sleep(self.base_ms * 1e-3 + n * self.us_per_token * 1e-6)
        return {"n_prompt": n}


def replica_sweep(replica_counts, *, n_requests: int = 64,
                  routing: str = "balanced", seed: int = 3) -> list:
    prompts = hetero_prompts(n_requests, seed=seed)
    rows = []
    for n in replica_counts:
        n = max(1, n)  # a service always runs at least one replica
        rh = Rhapsody(
            ResourceDescription(nodes=1,
                                cores_per_node=max(8, n_requests)),
            policy=ExecutionPolicy(routing=routing), n_workers=1)
        try:
            replica_set = rh.add_service(ServiceDescription(
                name="synth", replicas=n, factory=SyntheticServicer))
            descs = [TaskDescription(
                kind=TaskKind.INFERENCE, service="synth",
                payload={"prompt": p}, task_type="synthetic_inference")
                for p in prompts]
            t0 = time.perf_counter()
            uids = rh.submit(descs)
            if not rh.wait(uids, timeout=600):
                raise TimeoutError("synthetic stream timed out")
            dt = time.perf_counter() - t0
            lats = sorted(rh.tasks[u].duration for u in uids)
            per = [p["requests"]
                   for p in replica_set.stats()["per_replica"]]
            rows.append({
                "replicas": n, "routing": routing,
                "requests": n_requests, "seconds": dt,
                "req_per_s": n_requests / dt,
                "req_per_s_per_replica": n_requests / dt / n,
                "p50_ms": lats[len(lats) // 2] * 1e3,
                "p95_ms": lats[int(len(lats) * 0.95)] * 1e3,
                "p99_ms": lats[min(len(lats) - 1,
                                   int(len(lats) * 0.99))] * 1e3,
                "per_replica_requests": per,
            })
        finally:
            rh.close()
    return rows


# ---------------------------------------------------------------------------
# Prefix-affinity sweep: sessioned multi-turn streams, KV-reuse cost model
# ---------------------------------------------------------------------------


class SessionedServicer:
    """Synthetic engine with per-replica radix prefix caching: serving a
    prompt costs wall time only for the tokens this replica's cache does
    not already cover — where coverage is the longest common prefix with
    ANY sequence served here, exactly the engine's partial-resume rule (a
    diverging sibling prompt still covers the shared stem).  Affinity
    routing keeps a session on one replica, so its growing prompt re-pays
    only the new suffix; scattering it re-pays everything past the stem.
    Exposes ``residency_summary`` so the replica set can gossip this
    replica's cache contents to the router."""

    def __init__(self, base_ms: float = 1.0, us_per_token: float = 60.0):
        self.base_ms = base_ms
        self.us_per_token = us_per_token
        self._served = RadixIndex(capacity=512)  # models bounded KV space

    def handle(self, payload):
        p = payload["prompt"]
        cached, _ = self._served.longest_match(p)
        uncached = len(p) - cached
        time.sleep(self.base_ms * 1e-3 + uncached * self.us_per_token * 1e-6)
        self._served.insert(p, 0)  # one anonymous cache: compaction folds
        #                            a session's earlier, shorter turns
        return {"n_prompt": len(p), "uncached": uncached}

    def residency_summary(self, max_len: int = 128):
        return self._served.summary(max_entries=64, max_len=max_len)


def _turn_waves(bases: list, turns: int, turn_len: int, rng) -> list:
    """Grow each base by one heterogeneous-length turn per wave and
    shuffle each wave's arrival order — on a perfectly regular stream a
    load-balancing router stays accidentally sticky (every wave assigns
    identically), which no production request mix resembles.  Returns
    ``turns`` lists of ``len(bases)`` prompts (growing transcripts)."""
    grown = [list(b) for b in bases]
    waves = []
    for _ in range(turns):
        for s in range(len(grown)):
            ext = rng.randint(max(1, turn_len // 2), 2 * turn_len)
            grown[s] = grown[s] + list(rng.randint(0, 512, size=ext))
        wave = [list(g) for g in grown]
        rng.shuffle(wave)
        waves.append(wave)
    return waves


def sessioned_prompts(n_sessions: int, turns: int, *, prefix_len: int = 32,
                      turn_len: int = 24, seed: int = 0) -> list:
    """Per-turn waves of prompts: session s's turn t prompt is its UNIQUE
    base prefix plus t accumulated turn extensions (monotonically growing,
    like a chat transcript)."""
    rng = np.random.RandomState(seed)
    bases = [list(rng.randint(0, 512, size=prefix_len))
             for _ in range(n_sessions)]
    return _turn_waves(bases, turns, turn_len, rng)


def branching_prompts(n_agents: int, turns: int, *, stem_len: int = 48,
                      turn_len: int = 24, seed: int = 0) -> list:
    """Branching-session waves: the agentic-campaign pattern (paper
    §Fig. 7).  EVERY agent's prompt starts with one SHARED system-prompt
    stem — longer than the hashed affinity window, so ``request_signature``
    maps all agents to a single key — then diverges with the agent's own
    accumulated turns.  Hashed-LRU routing cannot tell the agents apart;
    radix longest-prefix-match homes each agent on the replica holding its
    own transcript, and the shared stem is still partially resumable
    anywhere."""
    rng = np.random.RandomState(seed)
    stem = list(rng.randint(0, 512, size=stem_len))
    return _turn_waves([stem] * n_agents, turns, turn_len, rng)


def affinity_run(n_replicas: int, policy: str, waves, *,
                 uniform=None) -> dict:
    """Drive sessioned turn-waves (and optionally a uniform stream) through
    the middleware under ``policy``; report hit rate + throughput."""
    # spill tuning per policy: hashed-LRU re-homes its whole (coarse) key
    # on every spill, so it needs a lax threshold to avoid thrash; radix
    # spills to the SECOND-longest prefix holder (which then serves the
    # shared stem warm), so an eager threshold spreads a shared-stem
    # stampede across replicas without losing reuse
    spill = 2.0 if policy == "radix_affinity" else 4.0
    rh = Rhapsody(
        ResourceDescription(nodes=1, cores_per_node=64),
        policy=ExecutionPolicy(routing=policy, affinity_spill_factor=spill),
        n_workers=1)
    try:
        rs = rh.add_service(ServiceDescription(
            name="sess", replicas=n_replicas, factory=SessionedServicer))
        prompts = uniform if uniform is not None else None
        n_requests = 0
        total_tokens = 0
        t0 = time.perf_counter()
        if prompts is not None:  # uniform stream: one wave, no sessions
            waves = [prompts]
        for wave in waves:
            descs = [TaskDescription(kind=TaskKind.INFERENCE, service="sess",
                                     payload={"prompt": p},
                                     task_type="sessioned_inference")
                     for p in wave]
            uids = rh.submit(descs)
            if not rh.wait(uids, timeout=600):
                raise TimeoutError("sessioned stream timed out")
            n_requests += len(uids)
            total_tokens += sum(len(p) for p in wave)
        dt = time.perf_counter() - t0
        stats = rs.stats()
        hits, misses = stats["prefix_hits"], stats["prefix_misses"]
        per = [p["requests"] for p in stats["per_replica"]]
        return {"replicas": n_replicas, "policy": policy,
                "requests": n_requests, "seconds": dt,
                "req_per_s": n_requests / dt,
                "tok_per_s": total_tokens / dt,
                "hit_rate": hits / max(1, hits + misses),
                "per_replica_requests": per}
    finally:
        rh.close()


def affinity_sweep(replica_counts, *, n_sessions: int = 8, turns: int = 8,
                   n_uniform: int = 192, seed: int = 0, repeats: int = 3,
                   policies=("least_loaded", "prefix_affinity",
                             "radix_affinity")) -> list:
    """Each (stream, policy, replicas) cell reports the best of ``repeats``
    runs: these are sub-second sleep-calibrated microbenchmarks, where OS
    thread scheduling adds +-30% run-to-run noise that best-of-N removes
    (the routing decisions themselves are deterministic per run)."""
    streams = [
        ("sessioned", sessioned_prompts(n_sessions, turns, seed=seed), None),
        ("branching", branching_prompts(n_sessions, turns, seed=seed + 2),
         None),
        ("uniform", None,
         hetero_prompts(n_uniform, seed=seed + 1, lo=32, hi=224)),
    ]
    rows = []
    for n in replica_counts:
        n = max(1, n)
        for policy in policies:
            for stream, waves, uniform in streams:
                r = max((affinity_run(n, policy, waves, uniform=uniform)
                         for _ in range(repeats)),
                        key=lambda x: x["req_per_s"])
                r["stream"] = stream
                rows.append(r)
    return rows


def _print_affinity(rows):
    print("stream,replicas,policy,requests,req_per_s,tok_per_s,hit_rate,"
          "per_replica_requests")
    for r in rows:
        print(f"{r['stream']},{r['replicas']},{r['policy']},"
              f"{r['requests']},{r['req_per_s']:.0f},{r['tok_per_s']:.0f},"
              f"{r['hit_rate']:.2f},\"{r['per_replica_requests']}\"")


def _print_sweep(rows):
    base = rows[0]["req_per_s"]
    print("replicas,req_per_s,per_replica_req_per_s,speedup,"
          "p50_ms,p95_ms,p99_ms,per_replica_requests")
    for r in rows:
        print(f"{r['replicas']},{r['req_per_s']:.0f},"
              f"{r['req_per_s_per_replica']:.0f},"
              f"{r['req_per_s'] / base:.2f}x,"
              f"{r['p50_ms']:.1f},{r['p95_ms']:.1f},{r['p99_ms']:.1f},"
              f"\"{r['per_replica_requests']}\"")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, nargs="+", default=None,
                    help="replica counts for the synthetic scaling sweep, "
                         "e.g. --replicas 1 2 4")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--routing", default="balanced", choices=tuple(ROUTERS))
    ap.add_argument("--affinity", action="store_true",
                    help="affinity routing sweep (radix longest-match vs "
                         "hashed-LRU vs least-loaded): sessioned, "
                         "branching (shared-stem agents), and uniform "
                         "streams; hit rate and throughput per replica "
                         "count")
    ap.add_argument("--sessions", type=int, default=8)
    ap.add_argument("--turns", type=int, default=8)
    ap.add_argument("--repeats", type=int, default=3,
                    help="best-of-N runs per cell (noise suppression)")
    ap.add_argument("--json", action="store_true",
                    help="emit rows as a JSON array instead of CSV")
    args = ap.parse_args()
    if args.affinity:
        rows = affinity_sweep(args.replicas or (1, 2, 4),
                              n_sessions=args.sessions,
                              turns=args.turns,
                              n_uniform=args.requests,
                              repeats=max(1, args.repeats))
        print(json.dumps(rows)) if args.json else _print_affinity(rows)
    elif args.replicas:
        rows = replica_sweep(args.replicas, n_requests=args.requests,
                             routing=args.routing)
        print(json.dumps(rows)) if args.json else _print_sweep(rows)
    else:
        main(Reporter())
