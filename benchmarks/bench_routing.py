"""Experiment 4 (Fig. 5c,d): batching-parameter sensitivity + routing policy.

(c) throughput vs ``max_num_seqs`` x ``max_num_batched_tokens`` on a fixed
prompt subset — the paper finds max_num_seqs dominates.
(d) strong scaling of a fixed heterogeneous prompt set (lognormal lengths,
the 4k-50k-token LUCID analogue scaled down) across 1-4 service instances
under randomized vs token-aware balanced routing.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro.configs import get_config
from repro.core import ResourceDescription, Rhapsody, ServiceDescription
from repro.core.router import make_router
from repro.serving.client import llm_service_factory

from .common import Reporter


def engine_cfg():
    return get_config("rhapsody-demo").scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512)


def hetero_prompts(n: int, seed: int = 0, lo: int = 8, hi: int = 96):
    rng = np.random.RandomState(seed)
    lens = np.clip(np.exp(rng.normal(3.0, 0.8, size=n)).astype(int), lo, hi)
    return [list(rng.randint(0, 512, size=int(L))) for L in lens]


# ---------------------------------------------------------------------------
# (c) batching parameter sensitivity
# ---------------------------------------------------------------------------


def sweep_batching(rep: Reporter, *, n_prompts: int = 24) -> list:
    cfg = engine_cfg()
    prompts = hetero_prompts(n_prompts, seed=1)
    out = []
    for max_num_seqs in (2, 4, 8):
        for max_tokens in (128, 512):
            rh = Rhapsody(ResourceDescription(nodes=1, cores_per_node=8),
                          n_workers=1)
            try:
                ep = rh.add_service(ServiceDescription(
                    name="llm", factory=llm_service_factory(
                        cfg, max_num_seqs=max_num_seqs,
                        max_num_batched_tokens=max_tokens,
                        max_len=128, prefill_buckets=(32, 64, 128))))
                t0 = time.perf_counter()
                futs = [ep.request({"prompt": p, "max_new_tokens": 8})
                        for p in prompts]
                res = [f.result(timeout=600) for f in futs]
                dt = time.perf_counter() - t0
                tokens = sum(len(r["tokens"]) + r["n_prompt"] for r in res)
                row = {"max_num_seqs": max_num_seqs,
                       "max_num_batched_tokens": max_tokens,
                       "tokens_per_s": tokens / dt, "seconds": dt}
                out.append(row)
                rep.add(f"exp4_batch_s{max_num_seqs}_t{max_tokens}",
                        dt * 1e6 / n_prompts,
                        f"{row['tokens_per_s']:.0f} tok/s")
            finally:
                rh.close()
    return out


# ---------------------------------------------------------------------------
# (d) routing policy strong scaling
# ---------------------------------------------------------------------------


def routed_run(n_services: int, policy: str, prompts) -> dict:
    cfg = engine_cfg()
    rh = Rhapsody(ResourceDescription(nodes=n_services, cores_per_node=8),
                  n_workers=1)
    try:
        eps = [rh.add_service(ServiceDescription(
            name=f"llm{i}", factory=llm_service_factory(
                cfg, max_num_seqs=4, max_len=128,
                prefill_buckets=(32, 64, 128), seed=i)))
            for i in range(n_services)]
        router = make_router(policy)
        assign = router.assign(prompts, n_services, cost=len)
        results = []
        lock = threading.Lock()

        def feed(si: int):
            futs = [eps[si].request({"prompt": prompts[i],
                                     "max_new_tokens": 8})
                    for i in assign[si]]
            out = [f.result(timeout=600) for f in futs]
            with lock:
                results.extend(out)

        t0 = time.perf_counter()
        th = [threading.Thread(target=feed, args=(s,))
              for s in range(n_services)]
        for t in th:
            t.start()
        for t in th:
            t.join()
        dt = time.perf_counter() - t0
        tokens = sum(len(r["tokens"]) + r["n_prompt"] for r in results)
        loads = [sum(len(prompts[i]) for i in a) for a in assign]
        return {"services": n_services, "policy": policy, "seconds": dt,
                "tokens_per_s": tokens / dt,
                "load_imbalance": max(loads) / max(1, min(loads))}
    finally:
        rh.close()


def main(rep: Reporter, *, n_prompts: int = 24,
         service_counts=(1, 2, 4)) -> dict:
    sens = sweep_batching(rep, n_prompts=min(12, n_prompts))
    prompts = hetero_prompts(n_prompts, seed=2)
    scaling = []
    for n in service_counts:
        for policy in ("random", "balanced"):
            r = routed_run(n, policy, prompts)
            scaling.append(r)
            rep.add(f"exp4_route_{policy}_s{n}",
                    r["seconds"] * 1e6 / n_prompts,
                    f"{r['tokens_per_s']:.0f} tok/s "
                    f"imbalance={r['load_imbalance']:.2f}")
    return {"sensitivity": sens, "scaling": scaling}


if __name__ == "__main__":
    main(Reporter())
