"""Experiment 3 (Fig. 5a,b): inference-at-scale baseline scalability.

Proportionally grows replicas / clients (paper: 1/1/10 -> 8/8/80; here
scaled to the host) with homogeneous prompts, measuring aggregate token
throughput and engine utilization (the GPU-utilization analogue: fraction
of decode-slot-steps occupied).  One service name, N replicas: clients all
hit the same replica set and the shared router spreads them.
"""
from __future__ import annotations

import threading
import time

from repro.configs import get_config
from repro.core import (ExecutionPolicy, ResourceDescription, Rhapsody,
                        ServiceDescription)
from repro.serving.client import llm_service_factory

from .common import Reporter


def engine_cfg():
    return get_config("rhapsody-demo").scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512)


def run_config(n_replicas: int, clients_per_replica: int,
               reqs_per_client: int = 8, prompt_len: int = 12,
               new_tokens: int = 8) -> dict:
    cfg = engine_cfg()
    rh = Rhapsody(ResourceDescription(nodes=n_replicas, cores_per_node=16),
                  policy=ExecutionPolicy(routing="least_loaded"),
                  n_workers=2)
    try:
        replica_set = rh.add_service(ServiceDescription(
            name="llm", replicas=n_replicas,
            factory=llm_service_factory(
                cfg, max_num_seqs=4, max_len=64, prefill_buckets=(16,))))
        results = []
        lock = threading.Lock()

        def client():
            futs = [replica_set.request({"prompt": [7] * prompt_len,
                                         "max_new_tokens": new_tokens})
                    for _ in range(reqs_per_client)]
            out = [f.result(timeout=600) for f in futs]
            with lock:
                results.extend(out)

        n_clients = n_replicas * clients_per_replica
        t0 = time.perf_counter()
        threads = [threading.Thread(target=client)
                   for _ in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        dt = time.perf_counter() - t0
        total_tokens = sum(len(r["tokens"]) + r["n_prompt"] for r in results)
        utils = [inst.servicer.stats.utilization
                 for inst in replica_set.instances]
        stats = replica_set.stats()
        return {
            "replicas": n_replicas,
            "clients": n_clients,
            "requests": len(results),
            "seconds": dt,
            "tokens_per_s": total_tokens / dt,
            "utilization": sum(utils) / len(utils),
            "per_replica_requests": [p["requests"]
                                     for p in stats["per_replica"]],
        }
    finally:
        rh.close()


def main(rep: Reporter, *, configs=((1, 2), (2, 2), (4, 2))) -> dict:
    out = []
    for n_replicas, cpc in configs:
        r = run_config(n_replicas, cpc)
        out.append(r)
        rep.add(f"exp3_infer_s{n_replicas}",
                1e6 * r["seconds"] / max(1, r["requests"]),
                f"{r['tokens_per_s']:.0f} tok/s util={r['utilization']:.2f} "
                f"clients={r['clients']}")
    return {"configs": out}


if __name__ == "__main__":
    main(Reporter())
