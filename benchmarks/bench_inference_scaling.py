"""Experiment 3 (Fig. 5a,b): inference-at-scale baseline scalability.

Proportionally grows nodes / service instances / clients (paper: 1/1/10 ->
8/8/80; here scaled to the host) with homogeneous prompts, measuring
aggregate token throughput and engine utilization (the GPU-utilization
analogue: fraction of decode-slot-steps occupied).
"""
from __future__ import annotations

import threading
import time

from repro.configs import get_config
from repro.core import (ResourceDescription, Rhapsody, ServiceDescription,
                        TaskDescription, TaskKind)
from repro.serving.client import llm_service_factory

from .common import Reporter


def engine_cfg():
    return get_config("rhapsody-demo").scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512)


def run_config(n_services: int, clients_per_service: int,
               reqs_per_client: int = 8, prompt_len: int = 12,
               new_tokens: int = 8) -> dict:
    cfg = engine_cfg()
    rh = Rhapsody(ResourceDescription(nodes=n_services, cores_per_node=16),
                  n_workers=2)
    try:
        eps = []
        for i in range(n_services):
            eps.append(rh.add_service(ServiceDescription(
                name=f"llm{i}",
                factory=llm_service_factory(
                    cfg, max_num_seqs=4, max_len=64,
                    prefill_buckets=(16,), seed=i),
            )))
        results = []
        lock = threading.Lock()

        def client(cid: int):
            ep = eps[cid % n_services]
            futs = [ep.request({"prompt": [7] * prompt_len,
                                "max_new_tokens": new_tokens})
                    for _ in range(reqs_per_client)]
            out = [f.result(timeout=600) for f in futs]
            with lock:
                results.extend(out)

        n_clients = n_services * clients_per_service
        t0 = time.perf_counter()
        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        dt = time.perf_counter() - t0
        total_tokens = sum(len(r["tokens"]) + r["n_prompt"] for r in results)
        utils = []
        for i in range(n_services):
            inst = rh.services.instances[f"llm{i}"]
            utils.append(inst.servicer.stats.utilization)
        return {
            "services": n_services,
            "clients": n_clients,
            "requests": len(results),
            "seconds": dt,
            "tokens_per_s": total_tokens / dt,
            "utilization": sum(utils) / len(utils),
        }
    finally:
        rh.close()


def main(rep: Reporter, *, configs=((1, 2), (2, 2), (4, 2))) -> dict:
    out = []
    for n_services, cpc in configs:
        r = run_config(n_services, cpc)
        out.append(r)
        rep.add(f"exp3_infer_s{n_services}",
                1e6 * r["seconds"] / max(1, r["requests"]),
                f"{r['tokens_per_s']:.0f} tok/s util={r['utilization']:.2f} "
                f"clients={r['clients']}")
    return {"configs": out}


if __name__ == "__main__":
    main(Reporter())
