"""Experiment 3 (Fig. 5a,b): inference-at-scale baseline scalability.

Proportionally grows replicas / clients (paper: 1/1/10 -> 8/8/80; here
scaled to the host) with homogeneous prompts, measuring aggregate token
throughput and engine utilization (the GPU-utilization analogue: fraction
of decode-slot-steps occupied).  One service name, N replicas: clients all
hit the same replica set and the shared router spreads them.

``--autoscale`` switches to the admission-controlled autoscaling scenario
(§III-C: services claim resources from the same partition ledger as
tasks): a step load against a replica set governed by a pluggable
autoscaler (``queue_depth`` | ``latency_slo``).  The ``step`` scenario
checks the policy converges to a stable replica count that holds the p95
SLO; the ``saturate`` scenario overloads past the partition's physical
capacity and checks scale-up is *denied* (SCALE_DENIED event +
``admission_denied`` stat) rather than overbooked, with
``Rhapsody.utilization()`` showing the replicas' live claims.

``--multi-model`` runs TWO model groups behind one service name under the
``weighted_capacity`` autoscaler: load shifts from one model to the other
inside a fully-occupied partition, and the scenario validates that the
SLO-violating group gains a replica by RETIRING one from the idle group
(capacity-neutral rebalance on the shared ledger), that per-group claims
sum to the ledger total, and that no request was served by a wrong-model
replica.

``--paged`` runs the block-paged KV comparison: a branching-session load
(one shared stem, many divergent suffixes) against a slot-pool engine and
BOTH paged decode paths (legacy gather round-trip and the default direct
kernel) at MEMORY PARITY (same KV cells), plus a small replicated paged
service whose per-group ``block_telemetry`` lands in the JSON.
Validation (``check_bench_json.py paged``) asserts exact greedy-token
equivalence across all three engines, concurrency above the slot pool's
``max_num_seqs`` ceiling, measured physical-block sharing (copy-on-write
reuse > 0), direct decode throughput no worse than the gather round-trip,
and sane free/shared block telemetry.

``--disagg`` compares DISAGGREGATED prefill/decode pools (paged-KV
handoff on first token, per-phase TTFT/ITL accounting) against unified
chunked prefill at equal replica count on a mixed long-prompt + chatty
stream, plus a deterministic recompute-fallback scenario (decode pool
pinned dry -> every import denied -> local recompute, never failure).
Validation (``check_bench_json.py disagg``) gates TTFT and ITL p95 both
>= 1.2x better under disaggregation, token-identical greedy output, zero
wrong-role completions, and a non-zero exercised fallback.
"""
from __future__ import annotations

import argparse
import json
import random
import threading
import time

import jax

from repro.configs import get_config
from repro.core import (ExecutionPolicy, ModelGroup, ResourceDescription,
                        ResourceRequirements, Rhapsody, ServiceDescription)
from repro.serving.client import llm_service_factory
from repro.serving.engine import SpecDecodeSession, make_engine_from_scratch

from .common import Reporter


def engine_cfg():
    return get_config("rhapsody-demo").scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512)


def run_config(n_replicas: int, clients_per_replica: int,
               reqs_per_client: int = 8, prompt_len: int = 12,
               new_tokens: int = 8) -> dict:
    cfg = engine_cfg()
    rh = Rhapsody(ResourceDescription(nodes=n_replicas, cores_per_node=16),
                  policy=ExecutionPolicy(routing="least_loaded"),
                  n_workers=2)
    try:
        replica_set = rh.add_service(ServiceDescription(
            name="llm", replicas=n_replicas,
            factory=llm_service_factory(
                cfg, max_num_seqs=4, max_len=64, prefill_buckets=(16,))))
        results = []
        lock = threading.Lock()

        def client():
            futs = [replica_set.request({"prompt": [7] * prompt_len,
                                         "max_new_tokens": new_tokens})
                    for _ in range(reqs_per_client)]
            out = [f.result(timeout=600) for f in futs]
            with lock:
                results.extend(out)

        n_clients = n_replicas * clients_per_replica
        t0 = time.perf_counter()
        threads = [threading.Thread(target=client)
                   for _ in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        dt = time.perf_counter() - t0
        total_tokens = sum(len(r["tokens"]) + r["n_prompt"] for r in results)
        utils = [inst.servicer.stats.utilization
                 for inst in replica_set.instances]
        stats = replica_set.stats()
        return {
            "replicas": n_replicas,
            "clients": n_clients,
            "requests": len(results),
            "seconds": dt,
            "tokens_per_s": total_tokens / dt,
            "utilization": sum(utils) / len(utils),
            "per_replica_requests": [p["requests"]
                                     for p in stats["per_replica"]],
        }
    finally:
        rh.close()


def main(rep: Reporter, *, configs=((1, 2), (2, 2), (4, 2))) -> dict:
    out = []
    for n_replicas, cpc in configs:
        r = run_config(n_replicas, cpc)
        out.append(r)
        rep.add(f"exp3_infer_s{n_replicas}",
                1e6 * r["seconds"] / max(1, r["requests"]),
                f"{r['tokens_per_s']:.0f} tok/s util={r['utilization']:.2f} "
                f"clients={r['clients']}")
    return {"configs": out}


# ---------------------------------------------------------------------------
# Autoscaling under a step load (admission-controlled by the ledger)
# ---------------------------------------------------------------------------


class TimedServicer:
    """Synthetic serial replica: each request occupies it for a fixed
    service time, so end-to-end latency is deterministic (queue wait +
    service) and the autoscaler's control behavior — not engine noise —
    is what the scenario measures.  ``tag`` marks results with the model
    group that served them, so the multi-model scenario can PROVE no
    request landed on a wrong-model replica."""

    def __init__(self, service_time_s: float = 0.02, tag: str = ""):
        self.service_time = service_time_s
        self.tag = tag
        self._q: list = []
        self._uid = 0
        self._cur = None
        self._done_at = 0.0

    def warmup(self):  # the autoscale scenarios run with warmup=True
        time.sleep(self.service_time)

    def submit(self, payload, **kw) -> int:
        self._uid += 1
        self._q.append(self._uid)
        return self._uid

    def step(self):
        now = time.perf_counter()
        out = []
        if self._cur is not None and now >= self._done_at:
            out.append((self._cur, {"ok": True, "served_by": self.tag}))
            self._cur = None
        if self._cur is None and self._q:
            self._cur = self._q.pop(0)
            self._done_at = now + self.service_time
        return out


def run_autoscale(autoscaler: str, scenario: str = "step", *,
                  capacity: int = 4, service_time_s: float = 0.02,
                  warm_s: float = 1.0, heavy_s: float = 5.0,
                  stable_window_s: float = 1.0) -> dict:
    """Step load against an autoscaled, admission-controlled replica set.

    ``step``: demand fits the partition — the policy must converge to a
    stable replica count (no membership change over the last
    ``stable_window_s``, >= 3 sustain windows) that holds the SLO.
    ``saturate``: demand exceeds the partition's ``capacity`` nodes — the
    set must pin at capacity with scale-up denied via event + stat.
    """
    if scenario == "step":
        clients, slo_ms, max_replicas = 8, 120.0, capacity
    elif scenario == "saturate":
        clients, slo_ms, max_replicas = 24, 60.0, 2 * capacity
    else:
        raise ValueError(f"unknown scenario {scenario!r}")
    interval = 0.05
    rh = Rhapsody(ResourceDescription(nodes=capacity, cores_per_node=1),
                  policy=ExecutionPolicy(
                      routing="least_loaded", autoscale=True,
                      autoscaler=autoscaler,
                      autoscale_min_replicas=1,
                      autoscale_max_replicas=max_replicas,
                      autoscale_high_depth=3.0, autoscale_low_depth=0.5,
                      autoscale_interval_s=interval, autoscale_sustain=2,
                      slo_p95_ms=slo_ms, slo_window_s=1.0,
                      warmup=True),
                  n_workers=2)
    try:
        rs = rh.add_service(ServiceDescription(
            name="llm", replicas=1,
            requirements=ResourceRequirements(ranks=1, cores_per_rank=1),
            factory=lambda: TimedServicer(service_time_s)))
        stop = threading.Event()
        served = [0] * clients

        def client(i):
            while not stop.is_set():
                try:
                    rs.request({"prompt": [i] * 8}).result(30.0)
                except (RuntimeError, TimeoutError):
                    break  # shutdown race / stalled runner at scenario end
                served[i] += 1

        trace: list = []  # (perf_counter, n_replicas) samples

        def sampler():
            while not stop.is_set():
                trace.append((time.perf_counter(), rs.n_replicas))
                time.sleep(interval / 2)

        threading.Thread(target=sampler, daemon=True).start()
        # phase 1: light load (one client) — the set should stay small
        light = threading.Thread(target=client, args=(0,), daemon=True)
        light.start()
        time.sleep(warm_s)
        # phase 2: step to full load
        heavy = [threading.Thread(target=client, args=(i,), daemon=True)
                 for i in range(1, clients)]
        for t in heavy:
            t.start()
        time.sleep(heavy_s)
        # measure while the load is still applied — reading any of these
        # after stop() would race the idle scale-down that follows
        p95 = rs.latency_p95(window_s=stable_window_s)
        util = rh.utilization()["default"]
        stats = rs.stats()
        final_replicas = rs.n_replicas
        t_end = time.perf_counter()
        stop.set()
        for t in [light] + heavy:
            t.join(timeout=30)
        tail = [n for t, n in trace
                if t_end - stable_window_s <= t <= t_end]
        return {
            "autoscaler": autoscaler,
            "scenario": scenario,
            "clients": clients,
            "capacity": capacity,
            "slo_p95_ms": slo_ms,
            "p95_ms": None if p95 is None else p95 * 1e3,
            "final_replicas": final_replicas,
            "converged": bool(tail) and len(set(tail)) == 1,
            "replica_trace": [n for _, n in trace],
            "requests": sum(served),
            "admission_denied": stats["admission_denied"],
            "service_cores": util["service_cores"],
            "service_replicas": util["service_replicas"],
            "core_utilization": util["cores"],
        }
    finally:
        rh.close()


def autoscale_sweep(policies=("queue_depth", "latency_slo"),
                    scenarios=("step", "saturate"), **kw) -> list:
    return [run_autoscale(p, s, **kw) for p in policies for s in scenarios]


# ---------------------------------------------------------------------------
# Multi-model replica set under shifting load (weighted_capacity rebalance)
# ---------------------------------------------------------------------------


def run_multi_model(*, capacity: int = 4, service_time_s: float = 0.02,
                    warm_s: float = 1.0, shift_s: float = 5.0,
                    stable_window_s: float = 1.0) -> list:
    """TWO model groups behind ONE service name, inside a partition the
    set fully occupies, governed by the ``weighted_capacity`` autoscaler.

    Phase 1: light, even load on both models.  Phase 2: the load SHIFTS —
    ``beta`` takes a heavy client burst while ``alpha`` goes idle.  With
    no free headroom, holding beta's SLO requires a REBALANCE: the scaler
    retires an alpha replica and admits a beta one on the freed claim.
    Emits one JSON row per model group; validation
    (``benchmarks/check_bench_json.py multimodel``) checks both models
    were served from the one set, per-group claims sum to the ledger's
    ``service_cores``, zero wrong-model routes (every TimedServicer tags
    the group that served it), and the rebalance is observable in
    ``stats()["per_group"]``.
    """
    interval = 0.05
    slo_ms = 60.0
    rh = Rhapsody(ResourceDescription(nodes=capacity, cores_per_node=1),
                  policy=ExecutionPolicy(
                      routing="least_loaded", autoscale=True,
                      autoscaler="weighted_capacity",
                      autoscale_min_replicas=1,
                      autoscale_max_replicas=capacity,
                      autoscale_low_depth=0.5,
                      autoscale_interval_s=interval, autoscale_sustain=2,
                      slo_p95_ms=slo_ms, slo_window_s=1.0,
                      warmup=True),
                  n_workers=2)
    try:
        rs = rh.add_service(ServiceDescription(
            name="llm", replicas=capacity,
            requirements=ResourceRequirements(ranks=1, cores_per_rank=1),
            models=[
                ModelGroup(name="alpha", weight=1.0,
                           factory=lambda: TimedServicer(service_time_s,
                                                         tag="alpha")),
                ModelGroup(name="beta", weight=1.0,
                           factory=lambda: TimedServicer(service_time_s,
                                                         tag="beta")),
            ]))
        start = rs.group_counts()
        stop = threading.Event()
        served = {"alpha": [0, 0], "beta": [0, 0]}  # [ok, wrong_route]
        lock = threading.Lock()

        def client(model, alive: threading.Event):
            while not stop.is_set() and alive.is_set():
                try:
                    r = rs.request({"prompt": [1] * 8, "model": model}
                                   ).result(30.0)
                except (RuntimeError, TimeoutError):
                    break  # shutdown race at scenario end
                with lock:
                    served[model][0] += 1
                    if r.get("served_by") != model:
                        served[model][1] += 1

        # phase 1: one light client per model
        alpha_alive = threading.Event()
        alpha_alive.set()
        both_alive = threading.Event()
        both_alive.set()
        threads = [threading.Thread(target=client, args=("alpha",
                                                         alpha_alive),
                                    daemon=True),
                   threading.Thread(target=client, args=("beta",
                                                         both_alive),
                                    daemon=True)]
        for t in threads:
            t.start()
        time.sleep(warm_s)
        # phase 2: load shifts — beta goes heavy, alpha goes idle
        alpha_alive.clear()
        heavy = [threading.Thread(target=client, args=("beta", both_alive),
                                  daemon=True) for _ in range(6)]
        for t in heavy:
            t.start()
        time.sleep(shift_s)
        # measure while the shifted load is still applied
        stats = rs.stats()
        util = rh.utilization()["default"]
        final = rs.group_counts()
        p95 = {g: rs.latency_p95(window_s=stable_window_s, group=g)
               for g in ("alpha", "beta")}
        stop.set()
        for t in threads + heavy:
            t.join(timeout=30)
        ledger_cores = util["service_cores"]
        rows = []
        for g in ("alpha", "beta"):
            gs = stats["per_group"][g]
            rows.append({
                "scenario": "multi_model",
                "group": g,
                "weight": gs["weight"],
                "hot": g == "beta",  # the group the load shifted ONTO
                "capacity": capacity,
                "requests": served[g][0],
                "wrong_route": served[g][1],
                "replicas_start": start[g],
                "replicas_final": gs["replicas"],
                "p95_ms": None if p95[g] is None else p95[g] * 1e3,
                "slo_p95_ms": gs["slo_p95_ms"],
                "service_cores": gs["cores"],
                "ledger_service_cores": ledger_cores,
                "ledger_models": util["service_models"],
                "admission_denied": stats["admission_denied"],
            })
        return rows
    finally:
        rh.close()


# ---------------------------------------------------------------------------
# Block-paged vs slot-pool engine on a branching-session load
# ---------------------------------------------------------------------------


def _drive(eng, prompts, new_tokens: int):
    """Submit all prompts at once and drain, tracking peak concurrency."""
    uids = [eng.submit(p, max_new_tokens=new_tokens) for p in prompts]
    done = {}
    peak = 0
    for _ in range(100000):
        if not eng.has_work():
            break
        eng.step()
        peak = max(peak, len(eng.running))
        for r in eng.collect_finished():
            done[r.uid] = r
    return [done[u].output for u in uids], peak


def _decode_burst(eng, prompts, new_tokens: int, repeats: int = 3) -> float:
    """Decode-phase throughput on a warm engine (the caller already
    compiled every jitted branch): admit + prefill run UNTIMED, then the
    pure decode steps are timed and ``decode_tokens/s`` reported — the
    number that isolates the gather round-trip vs direct-kernel decode
    cost from prefill and compile noise.  Best of ``repeats`` bursts, the
    standard microbenchmark answer to scheduler jitter on a shared CI
    host."""
    best = 0.0
    for _ in range(repeats):
        for p in prompts:
            eng.submit(p, max_new_tokens=new_tokens)

        def prefilling() -> bool:
            return bool(eng.queue) or any(
                r.pending_tokens and not r.done
                for r in eng.running.values())

        while eng.has_work() and prefilling():
            eng.step()
            eng.collect_finished()
        d0 = eng.stats.decode_tokens
        t0 = time.perf_counter()
        while eng.has_work():
            eng.step()
            eng.collect_finished()
        dt = time.perf_counter() - t0
        best = max(best, (eng.stats.decode_tokens - d0) / max(1e-9, dt))
    return best


def run_paged_compare(*, max_num_seqs: int = 4, max_len: int = 64,
                      block_size: int = 8, n_branches: int = 12,
                      prompt_len: int = 12, new_tokens: int = 6,
                      burst_tokens: int = 32) -> list:
    """Branching-session load (one stem, many divergent suffixes) on a
    slot-pool engine and BOTH block-paged decode paths at MEMORY PARITY
    (the paged pool defaults to the slot pool's KV cell count).  The stem
    runs first so its KV is resident when the branch burst arrives: the
    slot pool can resume ONE slot and must prefill the rest into its
    ``max_num_seqs`` slots, while the paged engines fork the stem's blocks
    into every branch's table (refcount sharing) and admit the whole burst
    at once, copy-on-write duplicating only the divergence-boundary block.

    Three rows: ``monolithic`` (slot pool), ``paged_gather`` (legacy
    gather/scatter round-trip, ``paged_decode_mode="gather"``), and
    ``paged`` (the default direct path — new K/V written straight into the
    tail block, attention through the block table).  Greedy outputs must
    match token-for-token across all three, and a warm decode-only burst
    measures ``decode_tokens_per_s`` so ``check_bench_json.py paged`` can
    gate direct >= gather."""
    cfg = engine_cfg()
    kw = dict(max_num_seqs=max_num_seqs, max_len=max_len,
              prefill_buckets=(16, 32), seed=0)
    rng = random.Random(0)
    stem = [rng.randrange(1, cfg.vocab) for _ in range(prompt_len)]
    branches = [stem + [rng.randrange(1, cfg.vocab) for _ in range(3)]
                for _ in range(n_branches)]
    outs = {}
    rows = []
    variants = (
        ("monolithic", {}),
        ("paged_gather", {"paged": True, "block_size": block_size,
                          "paged_decode_mode": "gather"}),
        ("paged", {"paged": True, "block_size": block_size}),  # direct
    )
    for name, extra in variants:
        eng = make_engine_from_scratch(cfg, **kw, **extra)
        t0 = time.perf_counter()
        stem_out, _ = _drive(eng, [stem], new_tokens)
        branch_out, peak = _drive(eng, branches, new_tokens)
        dt = time.perf_counter() - t0
        # everything is compiled now: measure pure decode throughput
        # (best of 3 warm bursts — see _decode_burst)
        decode_tps = _decode_burst(eng, branches, burst_tokens)
        outs[name] = stem_out + branch_out
        st = eng.stats
        tel = eng.block_telemetry()
        rows.append({
            "scenario": "paged_compare",
            "engine": name,
            "decode_mode": (extra.get("paged_decode_mode", "direct")
                            if extra.get("paged") else None),
            "max_num_seqs": max_num_seqs,
            "max_len": max_len,
            "block_size": block_size if extra.get("paged") else None,
            "num_blocks": eng.num_blocks if extra.get("paged") else None,
            "requests": 1 + n_branches,
            "seconds": dt,
            "tokens_per_s": st.tokens_per_s,
            "decode_tokens_per_s": decode_tps,
            "peak_concurrent": peak,
            "prefix_reuse_hits": st.prefix_reuse_hits,
            "prefix_cached_tokens": st.prefix_cached_tokens,
            "shared_block_peak": st.shared_block_peak,
            "cow_copies": st.cow_copies,
            # live pool gauges at quiescence (paged rows only)
            "free_blocks": tel["free_blocks"] if tel else None,
            "reserved_blocks": tel["reserved_blocks"] if tel else None,
        })
    match = (outs["monolithic"] == outs["paged_gather"] == outs["paged"])
    for r in rows:
        r["tokens_match"] = match
    return rows


def run_paged_service(*, n_replicas: int = 2, requests: int = 8,
                      prompt_len: int = 12, new_tokens: int = 6) -> list:
    """Small replicated PAGED service: exercises the telemetry pipeline
    the router's headroom weighting consumes — per-replica engine
    ``block_telemetry()`` aggregated per model group by
    ``ReplicaSet.stats()["per_group"][g]["block_telemetry"]``.  One JSON
    row per group; ``check_bench_json.py paged`` asserts the
    ``free_blocks``/``shared_blocks`` keys are present and sane."""
    cfg = engine_cfg()
    rh = Rhapsody(ResourceDescription(nodes=n_replicas, cores_per_node=16),
                  policy=ExecutionPolicy(routing="least_loaded"),
                  n_workers=2)
    try:
        rs = rh.add_service(ServiceDescription(
            name="llm", replicas=n_replicas,
            factory=llm_service_factory(
                cfg, max_num_seqs=4, max_len=64, prefill_buckets=(16,),
                paged=True, block_size=8)))
        futs = [rs.request({"prompt": [7] * prompt_len,
                            "max_new_tokens": new_tokens})
                for _ in range(requests)]
        for f in futs:
            f.result(timeout=600)
        stats = rs.stats()
        return [{
            "scenario": "paged_service",
            "group": g,
            "replicas": gs["replicas"],
            "requests": gs["requests"],
            "block_telemetry": gs["block_telemetry"],
        } for g, gs in stats["per_group"].items()]
    finally:
        rh.close()


# ---------------------------------------------------------------------------
# Disaggregated prefill/decode: per-phase SLOs vs unified chunked prefill
# ---------------------------------------------------------------------------


def _disagg_load(cfg, *, n_long: int, n_chat: int, long_len: int,
                 chat_len: int, long_new: int, chat_new: int,
                 seed: int = 0) -> list:
    """Mixed stream: long-prompt (RAG-like) requests whose chunked
    prefill is what steals decode budget in unified serving, interleaved
    with chatty short-prompt/long-decode sessions whose ITL that theft
    inflates.  Deterministically shuffled so both modes see the same
    arrival order."""
    rng = random.Random(seed)
    reqs = ([([rng.randrange(1, cfg.vocab) for _ in range(long_len)],
              long_new, "long") for _ in range(n_long)]
            + [([rng.randrange(1, cfg.vocab) for _ in range(chat_len)],
                chat_new, "chat") for _ in range(n_chat)])
    rng.shuffle(reqs)
    return reqs


def run_disagg(*, n_replicas: int = 4, n_long: int = 8, n_chat: int = 16,
               long_len: int = 96, chat_len: int = 8, long_new: int = 8,
               chat_new: int = 16, block_size: int = 8, max_len: int = 128,
               unified_budget: int = 32, prefill_budget: int = 256) -> list:
    """Disaggregated prefill/decode vs unified chunked prefill at EQUAL
    replica count, on a mixed long-prompt + chatty stream.

    Unified serving must pick ONE ``max_num_batched_tokens``: small
    chunks protect ITL but drag a long prompt's TTFT across many steps
    (each also paying the whole-prefix gather for interleaved decode);
    big chunks invert the pain.  Disaggregation removes the knob — the
    prefill pool runs huge chunks with NO decode to stall, the decode
    pool never sees a prefill chunk — so BOTH tails improve at the same
    replica count.  Greedy outputs must match a single reference engine
    token-for-token (the KV handoff moves state, never recomputes it
    differently), and every disagg request must finish on a decode
    replica via handoff (``wrong_role`` counts violations).

    Two ``disagg_compare`` rows (mode unified | disagg) with
    ``ttft_p95_ms`` / ``itl_p95_ms`` measured from per-request result
    stamps over a COMPILED service (a discarded warm wave triggers every
    jit bucket first); the disagg row carries the speedups the
    ``check_bench_json.py disagg`` gate enforces (>= 1.2x on both)."""
    from repro.core.autoscale import percentile
    from repro.serving.client import llm_model_group

    cfg = engine_cfg()
    reqs = _disagg_load(cfg, n_long=n_long, n_chat=n_chat,
                        long_len=long_len, chat_len=chat_len,
                        long_new=long_new, chat_new=chat_new)
    # reference: one engine, same seed-0 params as every replica
    ref = make_engine_from_scratch(
        cfg, seed=0, max_num_seqs=8, max_len=max_len, paged=True,
        block_size=block_size, num_blocks=160,
        max_num_batched_tokens=prefill_budget,
        prefill_buckets=(16, 32, 64, 128))
    ref_uids = [ref.submit(p, max_new_tokens=n) for p, n, _ in reqs]
    ref_done = ref.run()
    ref_out = [ref_done[u].output for u in ref_uids]

    base_kw = dict(max_num_seqs=8, max_len=max_len, paged=True,
                   block_size=block_size, num_blocks=160,
                   prefill_buckets=(16, 32, 64, 128))

    def one_mode(mode: str) -> dict:
        rh = Rhapsody(
            ResourceDescription(nodes=n_replicas, cores_per_node=16),
            policy=ExecutionPolicy(routing="least_loaded", warmup=True),
            n_workers=2)
        try:
            if mode == "disagg":
                n_pre = n_replicas // 2
                models = [
                    llm_model_group(
                        "prefill", cfg, role="prefill",
                        paired_with="decode", replicas=n_pre,
                        max_num_batched_tokens=prefill_budget, **base_kw),
                    llm_model_group(
                        "decode", cfg, role="decode",
                        replicas=n_replicas - n_pre,
                        max_num_batched_tokens=64, **base_kw),
                ]
                rs = rh.add_service(ServiceDescription(
                    name="llm", replicas=n_replicas, models=models))
                tag = {"model": "prefill"}
            else:
                rs = rh.add_service(ServiceDescription(
                    name="llm", replicas=n_replicas,
                    factory=llm_service_factory(
                        cfg, max_num_batched_tokens=unified_budget,
                        **base_kw)))
                tag = {}

            def wave(load):
                futs = [rs.request(dict({"prompt": p, "max_new_tokens": n},
                                        **tag)) for p, n, _ in load]
                return [f.result(timeout=600) for f in futs]

            # warm wave: same shape as the measured load so every jit
            # bucket (big prefill chunks, decode batch sizes, handoff
            # path) compiles BEFORE the timed wave; results discarded
            wave(_disagg_load(cfg, n_long=max(2, n_replicas),
                              n_chat=max(4, 2 * n_replicas),
                              long_len=long_len, chat_len=chat_len,
                              long_new=4, chat_new=6, seed=1))
            res = wave(reqs)
            ttfts = [r["ttft_s"] for r in res if r["ttft_s"] is not None]
            itls = [r["itl_s"] for r in res if r["itl_s"] is not None]
            match = all(r["tokens"] == o for r, o in zip(res, ref_out))
            wrong_role = (sum(1 for r in res
                              if not (r.get("handoff")
                                      and r.get("role") == "decode"))
                          if mode == "disagg" else 0)
            stats = rs.stats()
            hand = rs.handoff_totals()
            tp = percentile(ttfts, 0.95)
            ip = percentile(itls, 0.95)
            return {
                "scenario": "disagg_compare",
                "mode": mode,
                "replicas": n_replicas,
                "requests": len(reqs),
                "n_long": n_long, "n_chat": n_chat,
                "long_len": long_len, "chat_len": chat_len,
                "unified_budget": unified_budget,
                "prefill_budget": prefill_budget,
                "ttft_p95_ms": tp and tp * 1e3,
                "itl_p95_ms": ip and ip * 1e3,
                "tokens_match": match,
                "wrong_role": wrong_role,
                "handoffs": hand["imports"] + hand["recomputes"],
                "recomputes": hand["recomputes"],
                "per_group": {
                    g: {k: gs[k] for k in
                        ("role", "replicas", "requests", "ttft_p95_ms",
                         "itl_p95_ms", "handoff_exports",
                         "handoff_imports", "handoff_recomputes")}
                    for g, gs in stats["per_group"].items()},
            }
        finally:
            rh.close()

    rows = [one_mode("unified"), one_mode("disagg")]
    uni, dis = rows
    dis["ttft_speedup"] = (uni["ttft_p95_ms"] or 0.0) \
        / max(1e-9, dis["ttft_p95_ms"] or 0.0)
    dis["itl_speedup"] = (uni["itl_p95_ms"] or 0.0) \
        / max(1e-9, dis["itl_p95_ms"] or 0.0)
    return rows


def run_disagg_fallback(*, n_handoffs: int = 3, prompt_len: int = 24,
                        new_tokens: int = 6) -> list:
    """Recompute-on-miss: a decode pool too full to reserve an import's
    blocks must fall back to RECOMPUTING the sequence's prompt locally —
    degraded latency, never a failed request, and still token-identical
    output.  Deterministic servicer-level drive: a 9-block decode pool
    (one max_len=64 sequence needs all 8 usable) is pinned by a live
    long-budget occupant, so every import is denied while it runs."""
    from repro.serving.client import llm_service_factory

    cfg = engine_cfg()
    kw = dict(max_num_seqs=4, max_len=64, prefill_buckets=(16, 32),
              paged=True, block_size=8)
    pre = llm_service_factory(cfg, phase="prefill",
                              max_num_batched_tokens=256, **kw)()
    dec = llm_service_factory(cfg, phase="decode", num_blocks=9,
                              max_num_batched_tokens=64, **kw)()
    rng = random.Random(2)
    prompts = [[rng.randrange(1, cfg.vocab) for _ in range(prompt_len)]
               for _ in range(n_handoffs)]
    ref = make_engine_from_scratch(cfg, seed=0,
                                   max_num_batched_tokens=256, **kw)
    ref_uids = [ref.submit(p, max_new_tokens=new_tokens) for p in prompts]
    ref_done = ref.run()
    ref_out = {tuple(p): ref_done[u].output
               for p, u in zip(prompts, ref_uids)}

    # occupant: reserves the decode pool dry for its whole decode
    occ = dec.submit({"prompt": [3] * 30, "max_new_tokens": 30})
    dec.step()  # admit it (reserve_left now pins all 8 blocks)
    handoffs = []
    for p in prompts:
        pre.submit({"prompt": p, "max_new_tokens": new_tokens})
    for _ in range(100000):
        if len(handoffs) == n_handoffs:
            break
        for _, r in pre.step():
            if r.get("_handoff") is not None:
                handoffs.append(r["_handoff"])
    results = {}
    for pay in handoffs:  # every import denied -> recompute path
        dec.submit({"prompt": list(pay["prompt"]), "_import": pay})
    for _ in range(100000):
        if len(results) == n_handoffs + 1:
            break
        for uid, r in dec.step():
            results[uid] = r
    hs = dec.handoff_stats()
    # every recomputed sequence must reproduce the reference greedy
    # output (recompute = full local prefill + decode, same params)
    match = bool(handoffs)
    for pay in handoffs:
        want = ref_out[tuple(pay["prompt"])]
        match = match and any(
            r["tokens"] == want and r.get("recompute")
            for u, r in results.items() if u != occ)
    return [{
        "scenario": "disagg_fallback",
        "exports": n_handoffs,
        "imports": hs["imports"],
        "recomputes": hs["recomputes"],
        "completed": len(results),
        "tokens_match": match,
    }]


# ---------------------------------------------------------------------------
# Cross-group speculative decoding: draft-propose / target-verify pipeline
# ---------------------------------------------------------------------------


def _spec_cfg(n_layers: int):
    """A deep-enough model that per-forward cost scales with depth (the
    jitted forward is one XLA executable, so dispatch overhead is paid
    once per forward and layer compute dominates) — the regime where a
    shallow draft is genuinely cheaper than the deep target.  d512/12L
    puts one target step at ~15x a draft step, so the session's fixed
    per-round cost (host sync on the accept decision, slot rewinds) is
    small against the full-depth forwards it saves."""
    return get_config("rhapsody-demo").scaled(
        n_layers=n_layers, d_model=512, n_heads=8, n_kv_heads=4,
        head_dim=64, d_ff=2048, vocab=512)


def _identity_padded(draft_eng, target_eng, n_draft_layers: int):
    """Target params whose first ``n_draft_layers`` layers are the
    draft's and whose remaining layers are EXACT identities: the blocks
    are pre-norm with bias-free projections, so zeroing a layer's
    attention output projection and MLP down projection leaves only the
    residual path (``x + 0``).  The target then computes the draft's
    function bit-for-bit while paying full-depth cost — acceptance is
    1.0 by construction, isolating the propose/verify pipeline's best
    case without training a real draft."""
    dp, tp = draft_eng.params, target_eng.params
    blocks = jax.tree_util.tree_map(
        lambda t, d: t.at[:n_draft_layers].set(d),
        tp["blocks"], dp["blocks"])
    blocks["attn"]["o"]["w"] = \
        blocks["attn"]["o"]["w"].at[n_draft_layers:].set(0.0)
    blocks["mlp"]["down"]["w"] = \
        blocks["mlp"]["down"]["w"].at[n_draft_layers:].set(0.0)
    return {**dp, "blocks": blocks}


def _drain_timed(driver, prompts, new_tokens: int, repeats: int = 3):
    """Warm end-to-end drains: one untimed pass compiles every branch
    (prefill / decode / verify-extend), then the best decode-tokens/s
    over ``repeats`` timed passes — the microbenchmark answer to
    scheduler jitter on a shared CI host.  Returns (tok/s, outputs)."""
    stats = driver.stats  # the target engine's counters for a session
    best, outs = 0.0, None
    for i in range(repeats + 1):
        uids = [driver.submit(p, max_new_tokens=new_tokens)
                for p in prompts]
        d0 = stats.decode_tokens
        t0 = time.perf_counter()
        done = driver.run()
        dt = time.perf_counter() - t0
        outs = [done[u].output for u in uids]
        if i > 0:  # pass 0 is the compile warm-up
            best = max(best, (stats.decode_tokens - d0) / max(1e-9, dt))
    return best, outs


def run_speculative(*, k: int = 4, target_layers: int = 12,
                    draft_layers: int = 1, new_tokens: int = 40,
                    repeats: int = 3) -> list:
    """Three streams over identical prompts, one row each:

    ``vanilla``            — target-only greedy decode (the baseline).
    ``high_acceptance``    — SpecDecodeSession with a shallow draft the
                             identity-padded target agrees with 100%:
                             every round emits k+1 tokens for one
                             full-depth forward plus k shallow ones.
    ``low_acceptance``     — adversarial draft (independent weights,
                             ~zero acceptance) with the acceptance floor
                             armed: the session must disable itself
                             after the probe window and asymptote to
                             vanilla cost, not degrade below it.

    All three transcripts must match token-for-token (greedy
    equivalence); ``check_bench_json.py specdecode`` gates the speedups
    and the disable behavior."""
    tcfg = _spec_cfg(target_layers)
    dcfg = _spec_cfg(draft_layers)
    kw = dict(max_num_seqs=4, max_len=96, prefill_buckets=(16,))
    rng = random.Random(0)
    prompts = [[rng.randrange(1, tcfg.vocab) for _ in range(n)]
               for n in (12, 9, 12, 7)]

    drf = make_engine_from_scratch(dcfg, seed=0, **kw)

    def padded_target():
        tgt = make_engine_from_scratch(tcfg, seed=1, **kw)
        tgt.params = _identity_padded(drf, tgt, draft_layers)
        return tgt

    rows = []
    # vanilla: the target alone (identity-padded so all three streams
    # decode the SAME transcript)
    base_tps, ref = _drain_timed(padded_target(), prompts, new_tokens,
                                 repeats)
    rows.append({"stream": "vanilla", "decode_tokens_per_s": base_tps,
                 "acceptance_rate": None, "proposed": 0, "accepted": 0,
                 "enabled": None, "outs": ref})
    # high acceptance: the draft IS the target's function
    sess = SpecDecodeSession(padded_target(), drf, k=k)
    tps, outs = _drain_timed(sess, prompts, new_tokens, repeats)
    ss = sess.spec_stats()
    rows.append({"stream": "high_acceptance", "decode_tokens_per_s": tps,
                 "acceptance_rate": ss["acceptance_rate"],
                 "proposed": ss["proposed"], "accepted": ss["accepted"],
                 "enabled": ss["enabled"], "outs": outs})
    # low acceptance: an unrelated draft + the adaptive floor — the
    # session must turn itself off and fall back to vanilla stepping
    drf_bad = make_engine_from_scratch(dcfg, seed=7, **kw)
    sess = SpecDecodeSession(padded_target(), drf_bad, k=k,
                             min_acceptance=0.3, probe_proposals=32)
    tps, outs = _drain_timed(sess, prompts, new_tokens, repeats)
    ss = sess.spec_stats()
    rows.append({"stream": "low_acceptance", "decode_tokens_per_s": tps,
                 "acceptance_rate": ss["acceptance_rate"],
                 "proposed": ss["proposed"], "accepted": ss["accepted"],
                 "enabled": ss["enabled"], "outs": outs})
    match = all(r.pop("outs") == ref if r["stream"] != "vanilla"
                else bool(r.pop("outs")) for r in rows)
    for r in rows:
        r.update(scenario="speculative", k=k,
                 target_layers=target_layers, draft_layers=draft_layers,
                 new_tokens=new_tokens, tokens_match=match,
                 speedup_vs_vanilla=r["decode_tokens_per_s"]
                 / max(1e-9, base_tps))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--autoscale", action="store_true",
                    help="run the autoscaling step-load scenarios instead "
                         "of the fixed-replica throughput sweep")
    ap.add_argument("--multi-model", action="store_true",
                    help="run the two-model shifting-load rebalance "
                         "scenario (weighted_capacity autoscaler)")
    ap.add_argument("--paged", action="store_true",
                    help="run the block-paged vs slot-pool engine "
                         "comparison on a branching-session load")
    ap.add_argument("--speculative", action="store_true",
                    help="run the draft-propose / target-verify "
                         "speculative-decoding comparison (vanilla vs "
                         "high- and low-acceptance streams)")
    ap.add_argument("--disagg", action="store_true",
                    help="run the disaggregated prefill/decode vs unified "
                         "chunked-prefill comparison (mixed long-prompt + "
                         "chatty stream at equal replica count) plus the "
                         "recompute-fallback scenario")
    ap.add_argument("--disagg-replicas", type=int, default=4)
    ap.add_argument("--spec-k", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--branches", type=int, default=12)
    ap.add_argument("--policies", nargs="*",
                    default=["queue_depth", "latency_slo"])
    ap.add_argument("--scenarios", nargs="*",
                    default=["step", "saturate"])
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--heavy-s", type=float, default=5.0)
    ap.add_argument("--shift-s", type=float, default=5.0)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    if args.disagg:
        rows = (run_disagg(n_replicas=args.disagg_replicas)
                + run_disagg_fallback())
        if args.json:
            print(json.dumps(rows, indent=2))
        else:
            for r in rows:
                if r["scenario"] == "disagg_fallback":
                    print(f"[disagg] fallback exports={r['exports']} "
                          f"imports={r['imports']} "
                          f"recomputes={r['recomputes']} "
                          f"completed={r['completed']} "
                          f"match={r['tokens_match']}")
                    continue
                speed = ("" if r["mode"] == "unified" else
                         f" ttft_speedup={r['ttft_speedup']:.2f}x "
                         f"itl_speedup={r['itl_speedup']:.2f}x")
                print(f"[disagg] {r['mode']:>8s} x{r['replicas']} "
                      f"ttft_p95={r['ttft_p95_ms']:.0f}ms "
                      f"itl_p95={r['itl_p95_ms']:.0f}ms "
                      f"handoffs={r['handoffs']} "
                      f"recomputes={r['recomputes']} "
                      f"wrong_role={r['wrong_role']} "
                      f"match={r['tokens_match']}{speed}")
        raise SystemExit(0)
    if args.speculative:
        rows = run_speculative(k=args.spec_k)
        if args.json:
            print(json.dumps(rows, indent=2))
        else:
            for r in rows:
                acc = r["acceptance_rate"]
                print(f"[spec] {r['stream']:>16s} "
                      f"decode={r['decode_tokens_per_s']:.0f}tok/s "
                      f"({r['speedup_vs_vanilla']:.2f}x) "
                      f"acc={acc if acc is None else round(acc, 2)} "
                      f"proposed={r['proposed']} "
                      f"enabled={r['enabled']} "
                      f"match={r['tokens_match']}")
        raise SystemExit(0)
    if args.paged:
        rows = (run_paged_compare(block_size=args.block_size,
                                  n_branches=args.branches)
                + run_paged_service())
        if args.json:
            print(json.dumps(rows, indent=2))
        else:
            for r in rows:
                if r["scenario"] == "paged_service":
                    print(f"[paged] service group={r['group']} "
                          f"x{r['replicas']} "
                          f"telemetry={r['block_telemetry']}")
                    continue
                print(f"[paged] {r['engine']:>12s} "
                      f"peak={r['peak_concurrent']} "
                      f"(slots {r['max_num_seqs']}) "
                      f"shared={r['shared_block_peak']} "
                      f"cow={r['cow_copies']} "
                      f"hits={r['prefix_reuse_hits']} "
                      f"decode={r['decode_tokens_per_s']:.0f}tok/s "
                      f"free={r['free_blocks']} "
                      f"match={r['tokens_match']} "
                      f"{r['seconds']:.1f}s")
        raise SystemExit(0)
    if args.multi_model:
        rows = run_multi_model(capacity=args.capacity, shift_s=args.shift_s)
        if args.json:
            print(json.dumps(rows, indent=2))
        else:
            for r in rows:
                print(f"[multi-model] {r['group']:>6s} "
                      f"w={r['weight']} {'HOT ' if r['hot'] else 'idle'} "
                      f"replicas {r['replicas_start']}->"
                      f"{r['replicas_final']} "
                      f"p95={r['p95_ms'] and round(r['p95_ms'], 1)}ms "
                      f"(slo {r['slo_p95_ms']}ms) "
                      f"reqs={r['requests']} wrong={r['wrong_route']} "
                      f"cores={r['service_cores']}/"
                      f"{r['ledger_service_cores']}")
        raise SystemExit(0)
    if not args.autoscale:
        main(Reporter())
        raise SystemExit(0)
    rows = autoscale_sweep(args.policies, args.scenarios,
                           capacity=args.capacity, heavy_s=args.heavy_s)
    if args.json:
        print(json.dumps(rows, indent=2))
    else:
        for r in rows:
            print(f"[autoscale] {r['autoscaler']:>12s}/{r['scenario']:<8s} "
                  f"replicas={r['final_replicas']} "
                  f"converged={r['converged']} "
                  f"p95={r['p95_ms'] and round(r['p95_ms'], 1)}ms "
                  f"(slo {r['slo_p95_ms']}ms) "
                  f"denied={r['admission_denied']} "
                  f"claims={r['service_cores']}c/"
                  f"{r['service_replicas']}r")
