"""Experiment 5 (Fig. 6): coupled AI-HPC data-exchange overheads.

N simulation-inference pairs per "node"; each simulation produces a
4,000-element tensor (~16 KB, the paper's size) consumed by an inference
task.  Compares memory-based vs filesystem-based coupling, reports PUT/GET
latency and decomposes runtime into compute / data transfer / orchestration /
middleware overhead.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (ResourceDescription, Rhapsody, TaskDescription,
                        TaskKind)
from repro.core.coupling import make_store
from repro.substrate.simulation import surrogate_eval

from .common import Reporter

TENSOR = 4000  # elements (paper: 4,000-element tensors, ~16KB)


def sim_task(store, key: str, seed: int):
    rng = np.random.RandomState(seed)
    t0 = time.perf_counter()
    data = rng.normal(size=TENSOR).astype(np.float32)  # "simulation"
    compute = time.perf_counter() - t0
    store.put(key, data)
    return compute


def infer_task(store, key: str):
    data = store.get(key)
    t0 = time.perf_counter()
    out = surrogate_eval(data[:64][None, :].repeat(4, 0))
    compute = time.perf_counter() - t0
    return compute, float(out.mean())


def run_pairs(n_pairs: int, kind: str, n_workers: int = 4) -> dict:
    rh = Rhapsody(ResourceDescription(nodes=max(1, n_pairs // 32),
                                      cores_per_node=64),
                  n_workers=n_workers)
    store = make_store(kind)
    try:
        t0 = time.perf_counter()
        descs = []
        for i in range(n_pairs):
            s = TaskDescription(kind=TaskKind.COUPLED, fn=sim_task,
                                args=(store, f"pair{i}", i),
                                task_type="coupled_sim")
            f = TaskDescription(kind=TaskKind.COUPLED, fn=infer_task,
                                args=(store, f"pair{i}"),
                                dependencies=[s.uid],
                                task_type="coupled_infer")
            descs.extend([s, f])
        uids = rh.submit(descs)
        rh.wait(uids)
        total = time.perf_counter() - t0
        sim_compute = sum(rh.result(d.uid) for d in descs
                          if d.task_type == "coupled_sim")
        inf_compute = sum(rh.result(d.uid)[0] for d in descs
                          if d.task_type == "coupled_infer")
        st = store.stats.summary()
        transfer = (sum(store.stats.put_times)
                    + sum(store.stats.get_times))
        compute = sim_compute + inf_compute
        overhead = max(0.0, total - compute - transfer)
        return {
            "pairs": n_pairs, "store": kind, "total_s": total,
            "compute_s": compute, "transfer_s": transfer,
            "overhead_s": overhead,
            "overhead_frac": overhead / total,
            "avg_put_ms": st["avg_put_ms"], "avg_get_ms": st["avg_get_ms"],
            "bytes_moved": st["put_bytes"] + st["get_bytes"],
        }
    finally:
        store.close()
        rh.close()


def main(rep: Reporter, *, pair_counts=(32, 128)) -> dict:
    surrogate_eval(np.zeros((4, 64), np.float32))  # jit warmup off the clock
    out = []
    for n in pair_counts:
        for kind in ("memory", "filesystem"):
            r = run_pairs(n, kind)
            out.append(r)
            rep.add(f"exp5_{kind}_n{n}", r["total_s"] * 1e6 / n,
                    f"put={r['avg_put_ms']:.3f}ms get={r['avg_get_ms']:.3f}ms "
                    f"ovh={r['overhead_frac'] * 100:.1f}%")
    # paper headline: memory vs filesystem speedup
    for n in pair_counts:
        mem = next(r for r in out if r["pairs"] == n and r["store"] == "memory")
        fs = next(r for r in out if r["pairs"] == n and r["store"] == "filesystem")
        rep.add(f"exp5_speedup_n{n}", 0.0,
                f"mem_vs_fs={fs['total_s'] / mem['total_s']:.2f}x")
    return {"runs": out}


if __name__ == "__main__":
    main(Reporter())
