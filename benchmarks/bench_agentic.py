"""Experiment 6 (Fig. 7): agent decision rate vs AI-HPC realization rate.

A population of agents issues LLM decisions through a middleware service and
realizes each as HPC task submissions.  We verify sustained temporal overlap
(no phase separation) and bounded decision->realization lag.

``--qos`` runs the multi-tenant QoS campaign instead: agent sessions in two
priority classes plus batch FUNCTION tasks on one ledger, three phases
(unloaded high-class baseline; contended with QoS off; contended with QoS
on).  CI gates on the emitted JSON via ``check_bench_json.py qos``:
high-class p95 under saturating low-class load stays within 1.3x the
unloaded baseline, the low class keeps >= 80% of its no-QoS throughput
(weighted fairness is work-conserving, not starvation), preemptions match
resumes, and per-tenant accounting conserves with zero cross-tenant rows.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.configs import get_config
from repro.core import (ExecutionPolicy, ResourceDescription, Rhapsody,
                        ServiceDescription, TaskDescription)
from repro.core.agent import AgentConfig, run_agent_population
from repro.serving.client import llm_service_factory
from repro.substrate.simulation import surrogate_eval

from .common import Reporter


def run_population(n_agents: int, n_decisions: int = 4) -> dict:
    cfg = get_config("rhapsody-demo").scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512)
    rh = Rhapsody(ResourceDescription(nodes=4, cores_per_node=16),
                  n_workers=4)
    try:
        rh.add_service(ServiceDescription(
            name="llm", factory=llm_service_factory(
                cfg, max_num_seqs=8, max_len=64, prefill_buckets=(16,))))
        rng = np.random.RandomState(0)

        def payload(i):
            return {"prompt": list(rng.randint(0, 512, size=12)),
                    "max_new_tokens": 4}

        def make_task(i, j):
            return TaskDescription(
                fn=surrogate_eval, kwargs={"dim": 16, "hidden": 32,
                                           "seed": i * 131 + j},
                task_type="agent_tool")

        configs = [AgentConfig(name=f"a{k}", service="llm",
                               n_decisions=n_decisions,
                               tasks_per_decision=2,
                               decision_payload=payload,
                               make_task=make_task)
                   for k in range(n_agents)]
        summary = run_agent_population(rh, configs)
        dec = rh.events.windowed_rate("DECISION", window=0.5, tag="decision")
        arr = rh.events.windowed_rate("RUNNING", window=0.5)
        lags = rh.events.realization_lag()
        # temporal overlap: fraction of decision windows with nonzero ARR
        arr_t = {round(t, 3): r for t, r in arr}
        overlap = 0
        for t, r in dec:
            if r > 0 and any(abs(t - t2) < 0.5 and r2 > 0
                             for t2, r2 in arr):
                overlap += 1
        return {
            "agents": n_agents,
            "decisions": summary["decisions"],
            "tasks": summary["tasks"],
            "mean_lag_s": float(np.mean(lags)) if lags else 0.0,
            "p95_lag_s": float(np.percentile(lags, 95)) if lags else 0.0,
            "overlap_frac": overlap / max(1, len(dec)),
            "peak_decision_rate": max((r for _, r in dec), default=0.0),
            "peak_arr": max((r for _, r in arr), default=0.0),
            "errors": summary["errors"],
        }
    finally:
        rh.close()


def _p95(xs):
    return float(np.percentile(xs, 95)) if xs else None


def _qos_phase(phase: str, cfg, *, qos_on: bool, with_low: bool,
               n_high=2, n_low=6, high_decisions=24,
               low_decisions=8) -> dict:
    """One phase of the QoS campaign on a fresh single-replica service.

    A SINGLE engine seat and six saturating low-class agents (pure
    request loops, four decisions pipelined each: up to 24 outstanding
    against one seat) keep the replica oversubscribed the whole phase, so high-class isolation has to come
    from the scheduler (queue reordering + decode preemption), not from
    idle capacity — and with one seat there is no batch sharing, so the
    contended high-class latency is directly comparable to the unloaded
    baseline: any excess IS queueing.  A batch of FUNCTION tasks rides the same ledger's worker
    pool in every phase — the paper's hybrid AI-HPC mix, not an
    inference-only microbench (and symmetric noise: the baseline pays
    the same task-pool tax as the contended phases)."""
    rh = Rhapsody(ResourceDescription(nodes=4, cores_per_node=16),
                  policy=ExecutionPolicy(routing="round_robin"),
                  n_workers=2)
    try:
        rs = rh.add_service(ServiceDescription(
            name="llm", replicas=1,
            factory=llm_service_factory(
                cfg, max_num_seqs=1, max_len=80, paged=True, block_size=8,
                num_blocks=26, prefill_buckets=(16, 32), qos=qos_on)))
        rng = np.random.RandomState(0)

        def high_payload(i):
            return {"prompt": list(rng.randint(0, 512, size=16)),
                    "max_new_tokens": 24}

        def low_payload(i):
            return {"prompt": list(rng.randint(0, 512, size=24)),
                    "max_new_tokens": 16}

        def make_task(i, j):
            return TaskDescription(
                fn=surrogate_eval, kwargs={"dim": 16, "hidden": 32,
                                           "seed": i * 131 + j},
                task_type="agent_tool")

        def build(tag, highs, lows):
            cfgs = [AgentConfig(name=f"{tag}hi{k}", service="llm",
                                n_decisions=highs,
                                tasks_per_decision=2,
                                decision_payload=high_payload,
                                make_task=make_task, think_time=0.15,
                                tenant="interactive", priority="high")
                    for k in range(n_high)]
            if with_low:
                cfgs += [AgentConfig(name=f"{tag}lo{k}", service="llm",
                                     n_decisions=lows,
                                     tasks_per_decision=0,
                                     decision_payload=low_payload,
                                     think_time=0.0, pipeline_depth=4,
                                     tenant="batch", priority="low")
                         for k in range(n_low)]
            return cfgs

        # dress rehearsal: an untimed miniature of the EXACT measured
        # workload, so every JIT shape (prefill buckets, multi-seat decode
        # batches, preemption readmits) is compiled before the clock
        # starts — measured p95s reflect queueing, which is what QoS
        # controls, not stray compiles
        run_agent_population(rh, build("warm-", 2, 2))
        # the batch FUNCTION leg: plain HPC tasks coexisting with both
        # agent classes on the one resource ledger for the whole phase
        batch_uids = rh.submit([make_task(97, j) for j in range(16)])
        t0 = time.perf_counter()
        summary = run_agent_population(rh, build("", high_decisions,
                                                 low_decisions))
        elapsed = time.perf_counter() - t0
        # service-side per-class p95s (envelope submission -> servicer
        # resolution): the isolation gate reads THESE — client-side agent
        # latencies also include agent-thread wakeup under CPU load,
        # which is harness noise, not scheduling
        svc_high = rs.latency_p95(tenant_class="high", started_after=t0)
        svc_low = rs.latency_p95(tenant_class="low", started_after=t0)
        rh.wait(batch_uids)
        batch_done = sum(1 for u in batch_uids
                         if rh.tasks[u].state.name == "DONE")
        by_cls = summary["latencies_by_class"]
        stats = rh.get_service("llm").stats()
        low_done = len(by_cls.get("low", []))
        return {
            "scenario": "qos_campaign",
            "phase": phase,
            "qos": qos_on,
            "elapsed_s": elapsed,
            "high_p95_s": svc_high,
            "low_p95_s": svc_low,
            "agent_high_p95_s": _p95(by_cls.get("high", [])),
            "agent_low_p95_s": _p95(by_cls.get("low", [])),
            "high_decisions": len(by_cls.get("high", [])),
            "low_decisions": low_done,
            "low_throughput_per_s": (low_done / elapsed if with_low
                                     else None),
            "decision_errors": summary["decision_errors"],
            "agent_errors": summary["errors"],
            "batch_tasks": len(batch_uids),
            "batch_completed": batch_done,
            "per_tenant": stats["per_tenant"],
            "qos_counters": stats["qos"],
            "expected_tenants": (["batch", "interactive"] if with_low
                                 else ["interactive"]),
        }
    finally:
        rh.close()


def run_qos_campaign(**kw) -> list:
    cfg = get_config("rhapsody-demo").scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512)
    return [
        _qos_phase("baseline_high", cfg, qos_on=True, with_low=False, **kw),
        _qos_phase("no_qos", cfg, qos_on=False, with_low=True, **kw),
        _qos_phase("qos", cfg, qos_on=True, with_low=True, **kw),
    ]


def main(rep: Reporter, *, populations=(4, 16)) -> dict:
    out = []
    for n in populations:
        r = run_population(n)
        out.append(r)
        rep.add(f"exp6_agents_{n}", r["mean_lag_s"] * 1e6,
                f"lag_p95={r['p95_lag_s']:.3f}s overlap={r['overlap_frac']:.2f} "
                f"arr_peak={r['peak_arr']:.1f}/s")
    return {"populations": out}


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--qos", action="store_true",
                    help="run the multi-tenant QoS isolation campaign")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    if args.qos:
        rows = run_qos_campaign()
        if args.json:
            print(json.dumps(rows, indent=2))
        else:
            for r in rows:
                print(f"{r['phase']:>14}: high_p95="
                      f"{(r['high_p95_s'] or 0) * 1e3:.1f}ms "
                      f"low_tp={r['low_throughput_per_s'] or 0:.2f}/s "
                      f"qos={r['qos_counters']}")
    else:
        main(Reporter())
