"""Experiment 6 (Fig. 7): agent decision rate vs AI-HPC realization rate.

A population of agents issues LLM decisions through a middleware service and
realizes each as HPC task submissions.  We verify sustained temporal overlap
(no phase separation) and bounded decision->realization lag.
"""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core import (ResourceDescription, Rhapsody, ServiceDescription,
                        TaskDescription)
from repro.core.agent import AgentConfig, run_agent_population
from repro.serving.client import llm_service_factory
from repro.substrate.simulation import surrogate_eval

from .common import Reporter


def run_population(n_agents: int, n_decisions: int = 4) -> dict:
    cfg = get_config("rhapsody-demo").scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512)
    rh = Rhapsody(ResourceDescription(nodes=4, cores_per_node=16),
                  n_workers=4)
    try:
        rh.add_service(ServiceDescription(
            name="llm", factory=llm_service_factory(
                cfg, max_num_seqs=8, max_len=64, prefill_buckets=(16,))))
        rng = np.random.RandomState(0)

        def payload(i):
            return {"prompt": list(rng.randint(0, 512, size=12)),
                    "max_new_tokens": 4}

        def make_task(i, j):
            return TaskDescription(
                fn=surrogate_eval, kwargs={"dim": 16, "hidden": 32,
                                           "seed": i * 131 + j},
                task_type="agent_tool")

        configs = [AgentConfig(name=f"a{k}", service="llm",
                               n_decisions=n_decisions,
                               tasks_per_decision=2,
                               decision_payload=payload,
                               make_task=make_task)
                   for k in range(n_agents)]
        summary = run_agent_population(rh, configs)
        dec = rh.events.windowed_rate("DECISION", window=0.5, tag="decision")
        arr = rh.events.windowed_rate("RUNNING", window=0.5)
        lags = rh.events.realization_lag()
        # temporal overlap: fraction of decision windows with nonzero ARR
        arr_t = {round(t, 3): r for t, r in arr}
        overlap = 0
        for t, r in dec:
            if r > 0 and any(abs(t - t2) < 0.5 and r2 > 0
                             for t2, r2 in arr):
                overlap += 1
        return {
            "agents": n_agents,
            "decisions": summary["decisions"],
            "tasks": summary["tasks"],
            "mean_lag_s": float(np.mean(lags)) if lags else 0.0,
            "p95_lag_s": float(np.percentile(lags, 95)) if lags else 0.0,
            "overlap_frac": overlap / max(1, len(dec)),
            "peak_decision_rate": max((r for _, r in dec), default=0.0),
            "peak_arr": max((r for _, r in arr), default=0.0),
            "errors": summary["errors"],
        }
    finally:
        rh.close()


def main(rep: Reporter, *, populations=(4, 16)) -> dict:
    out = []
    for n in populations:
        r = run_population(n)
        out.append(r)
        rep.add(f"exp6_agents_{n}", r["mean_lag_s"] * 1e6,
                f"lag_p95={r['p95_lag_s']:.3f}s overlap={r['overlap_frac']:.2f} "
                f"arr_peak={r['peak_arr']:.1f}/s")
    return {"populations": out}


if __name__ == "__main__":
    main(Reporter())
