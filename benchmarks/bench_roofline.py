"""Roofline report: reads the dry-run sweep artifacts (§Roofline).

Re-emits the per-(arch x shape x mesh) three-term roofline from
``results/dryrun_all.json`` (produced by ``repro.launch.dryrun``); does not
itself compile.  Run ``PYTHONPATH=src python -m repro.launch.dryrun
--both-meshes --out results/dryrun_all.json`` to regenerate.
"""
from __future__ import annotations

import json
import os

from .common import RESULTS_DIR, Reporter

SWEEP = os.path.join(RESULTS_DIR, "dryrun_all.json")


def main(rep: Reporter) -> dict:
    if not os.path.exists(SWEEP):
        rep.add("roofline_missing", 0.0,
                "run repro.launch.dryrun --both-meshes first")
        return {}
    with open(SWEEP) as f:
        records = json.load(f)
    ok = 0
    for r in records:
        if r["status"] != "ok":
            continue
        if r["multi_pod"]:
            continue  # roofline table is single-pod per the assignment
        ok += 1
        rl = r["roofline"]
        dom = max(rl["t_compute_s"], rl["t_memory_s"], rl["t_collective_s"])
        frac = rl["t_compute_s"] / max(1e-12, dom)
        rep.add(
            f"roofline_{r['arch']}_{r['shape']}",
            dom * 1e6,
            f"bn={rl['bottleneck']} comp={rl['t_compute_s']:.3e}s "
            f"mem={rl['t_memory_s']:.3e}s coll={rl['t_collective_s']:.3e}s "
            f"frac={frac:.3f} useful={rl['useful_flops_ratio']:.2f}",
        )
    n_err = sum(1 for r in records if r["status"] == "error")
    n_skip = sum(1 for r in records if r["status"] == "skipped")
    rep.add("roofline_summary", 0.0,
            f"cells_ok={ok} errors={n_err} skipped={n_skip} "
            f"(skips = long_500k on full-attention archs)")
    return {"records": ok}


if __name__ == "__main__":
    main(Reporter())
