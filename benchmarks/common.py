"""Shared benchmark helpers + CSV emission."""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


class Reporter:
    def __init__(self):
        self.rows = []

    def add(self, name: str, us_per_call: float, derived: str = ""):
        self.rows.append((name, us_per_call, derived))
        print(f"{name},{us_per_call:.2f},{derived}", flush=True)

    def save_json(self, name: str, payload):
        os.makedirs(RESULTS_DIR, exist_ok=True)
        path = os.path.join(RESULTS_DIR, name)
        with open(path, "w") as f:
            json.dump(payload, f, indent=1)
        return path


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return time.perf_counter() - t0, out
