"""Experiment 2 (Fig. 4): heterogeneity width under a mixed campaign.

Task types along the paper's three heterogeneity dimensions — execution
model (serial vs multi-rank), accelerator usage (cpu vs gpu-tagged), and
rank scale — all with real jitted payloads.  Submission order is driven only
by dependencies; HW(t) measures how many distinct types the runtime overlaps.
"""
from __future__ import annotations

import random
import time

from repro.core import (ExecutionPolicy, ResourceDescription, Rhapsody,
                        ResourceRequirements, TaskDescription, TaskKind)
from repro.substrate.simulation import heat_stencil, lj_step, surrogate_eval

from .common import Reporter

TASK_TYPES = [
    # (type label, kind, fn, kwargs, ranks, cores/rank, gpus/rank)
    ("serial_cpu_analysis", TaskKind.FUNCTION, surrogate_eval,
     {"dim": 32, "hidden": 64}, 1, 1, 0),
    ("serial_gpu_score", TaskKind.FUNCTION, surrogate_eval,
     {"dim": 64, "hidden": 128}, 1, 1, 1),
    ("mpi_cpu_sim_small", TaskKind.EXECUTABLE, heat_stencil,
     {"n": 48, "steps": 8}, 2, 2, 0),
    ("mpi_cpu_sim_large", TaskKind.EXECUTABLE, heat_stencil,
     {"n": 96, "steps": 16}, 8, 2, 0),
    ("mpi_gpu_md", TaskKind.EXECUTABLE, lj_step,
     {"n_particles": 96, "steps": 8}, 4, 1, 1),
    ("preprocess", TaskKind.FUNCTION, surrogate_eval,
     {"dim": 8, "hidden": 16}, 1, 1, 0),
]


def build_campaign(n_pipelines: int, seed: int = 0):
    """Pipelines of sim -> analysis -> surrogate with cross-type diversity."""
    rng = random.Random(seed)
    descs = []
    for p in range(n_pipelines):
        sim_t = rng.choice(TASK_TYPES[2:5])
        sim = TaskDescription(
            kind=sim_t[1], fn=sim_t[2], kwargs=dict(sim_t[3], seed=p),
            requirements=ResourceRequirements(ranks=sim_t[4],
                                              cores_per_rank=sim_t[5],
                                              gpus_per_rank=sim_t[6]),
            task_type=sim_t[0])
        pre_t = TASK_TYPES[5]
        pre = TaskDescription(
            kind=pre_t[1], fn=pre_t[2], kwargs=dict(pre_t[3], seed=p),
            task_type=pre_t[0], dependencies=[sim.uid])
        an_t = rng.choice(TASK_TYPES[0:2])
        analysis = TaskDescription(
            kind=an_t[1], fn=an_t[2], kwargs=dict(an_t[3], seed=p),
            requirements=ResourceRequirements(gpus_per_rank=an_t[6]),
            task_type=an_t[0], dependencies=[pre.uid])
        descs.extend([sim, pre, analysis])
    return descs


def run_campaign(n_pipelines: int, nodes: int, n_workers: int = 8) -> dict:
    rh = Rhapsody(ResourceDescription(nodes=nodes, cores_per_node=16,
                                      gpus_per_node=4),
                  policy=ExecutionPolicy(backfill=True),
                  n_workers=n_workers)
    try:
        descs = build_campaign(n_pipelines)
        t0 = time.perf_counter()
        uids = rh.submit(descs)
        rh.wait(uids)
        dt = time.perf_counter() - t0
        hw = rh.events.heterogeneity_width()
        peak = max((h for _, h in hw), default=0)
        sustained = sorted(h for _, h in hw)[len(hw) // 2] if hw else 0
        return {
            "pipelines": n_pipelines,
            "nodes": nodes,
            "seconds": dt,
            "peak_hw": peak,
            "median_hw": sustained,
            "timeline_points": len(hw),
            "distinct_types": len({d.task_type for d in descs}),
        }
    finally:
        rh.close()


def main(rep: Reporter, *, scales=((24, 4), (48, 16))) -> dict:
    out = []
    for n_pipelines, nodes in scales:
        r = run_campaign(n_pipelines, nodes)
        out.append(r)
        rep.add(f"exp2_hw_n{nodes}", r["seconds"] * 1e6 / max(1, r['pipelines']),
                f"peak_hw={r['peak_hw']} median_hw={r['median_hw']} "
                f"types={r['distinct_types']}")
    return {"campaigns": out}


if __name__ == "__main__":
    main(Reporter())
