"""Validate the JSON a CI bench smoke emitted: structural and invariant
checks, NOT perf thresholds (throughput is too noisy for CI; invariants —
conservation, admission control, rebalance direction, zero wrong-model
routes — are not).

Usage::

    python benchmarks/check_bench_json.py affinity   /tmp/affinity.json
    python benchmarks/check_bench_json.py autoscale  /tmp/autoscale.json
    python benchmarks/check_bench_json.py multimodel /tmp/multimodel.json
    python benchmarks/check_bench_json.py paged      /tmp/paged.json
    python benchmarks/check_bench_json.py specdecode /tmp/specdecode.json
    python benchmarks/check_bench_json.py disagg     /tmp/disagg.json
    python benchmarks/check_bench_json.py qos        /tmp/qos.json

Each checker takes the decoded rows and raises ``CheckFailed`` with a
pointed message on the first violated invariant — these used to live as
heredoc assert blocks inside ``ci.yml``, where nothing could unit-test
them; now ``tests/test_check_bench_json.py`` feeds them canned good/bad
rows.
"""
from __future__ import annotations

import argparse
import json
import sys


class CheckFailed(AssertionError):
    """A bench JSON violated an invariant the smoke is meant to gate on."""


def _require(cond, msg, ctx=None):
    if not cond:
        raise CheckFailed(f"{msg}" + (f": {ctx!r}" if ctx is not None
                                      else ""))


def check_affinity(rows: list) -> None:
    """bench_routing --affinity: all 3 policies x 3 streams present, every
    row well-formed, sticky policies actually exercise the affinity path
    on session-shaped streams."""
    _require(bool(rows), "affinity sweep emitted no rows")
    streams = {r.get("stream") for r in rows}
    _require({"sessioned", "branching", "uniform"} <= streams,
             "missing streams", streams)
    policies = {r.get("policy") for r in rows}
    _require({"least_loaded", "prefix_affinity", "radix_affinity"}
             <= policies, "missing policies", policies)
    for r in rows:
        _require({"policy", "replicas", "requests", "req_per_s",
                  "hit_rate"} <= set(r), "row missing keys", r)
        _require(r["requests"] > 0 and r["req_per_s"] > 0,
                 "empty or zero-throughput row", r)
        # sanity (not perf): sticky policies must see hits on streams
        # that repeat prefixes
        if r["policy"] != "least_loaded" and \
                r["stream"] in ("sessioned", "branching"):
            _require(r["hit_rate"] > 0, "sticky policy never hit", r)


def check_autoscale(rows: list) -> None:
    """bench_inference_scaling --autoscale: both policies x both
    scenarios, claims on the shared ledger match live replicas, step
    converges undenied, saturate pins at capacity WITH denials, and the
    SLO policy holds its target under the step load."""
    cells = {(r.get("autoscaler"), r.get("scenario")): r for r in rows}
    _require(set(cells) == {("queue_depth", "step"),
                            ("queue_depth", "saturate"),
                            ("latency_slo", "step"),
                            ("latency_slo", "saturate")},
             "wrong scenario matrix", sorted(cells))
    for r in rows:
        # services live on the shared ledger: utilization() must reflect
        # every live replica's claim
        _require(r["service_replicas"] == r["final_replicas"],
                 "ledger replicas != live replicas", r)
        _require(r["service_cores"] == r["final_replicas"],
                 "ledger cores != live replicas", r)
        _require(r["requests"] > 0, "scenario served nothing", r)
    for (pol, sc), r in cells.items():
        if sc == "step":  # demand fits: stable count, nothing denied
            _require(r["converged"], "step scenario did not converge", r)
            _require(r["admission_denied"] == 0,
                     "step scenario saw denials", r)
        else:  # demand exceeds the partition: capped + denied
            _require(r["final_replicas"] == r["capacity"],
                     "saturate did not pin at capacity", r)
            _require(r["admission_denied"] > 0,
                     "saturate scenario was never denied", r)
    slo = cells[("latency_slo", "step")]
    _require(slo["p95_ms"] is not None, "SLO step has no p95", slo)
    _require(slo["p95_ms"] <= 1.5 * slo["slo_p95_ms"],
             "SLO step p95 blew the target", slo)


def check_multimodel(rows: list) -> None:
    """bench_inference_scaling --multi-model: both models served from ONE
    set, per-group claims sum to the ledger's claimed total, no request
    was served by a wrong-model replica, and the shifting load produced a
    rebalance — the SLO-violating (hot) group gained a replica while the
    idle group shrank."""
    _require(len(rows) == 2, "expected one row per model group", rows)
    groups = {r.get("group") for r in rows}
    _require(len(groups) == 2, "rows must cover two distinct groups",
             groups)
    ledger = {r["ledger_service_cores"] for r in rows}
    _require(len(ledger) == 1, "rows disagree on the ledger total", rows)
    _require(sum(r["service_cores"] for r in rows) == ledger.pop(),
             "per-group cores do not sum to the ledger's claimed total",
             rows)
    hot = [r for r in rows if r.get("hot")]
    idle = [r for r in rows if not r.get("hot")]
    _require(len(hot) == 1 and len(idle) == 1,
             "exactly one group must be the shifted-load target", rows)
    for r in rows:
        _require(r["requests"] > 0,
                 "a model group served nothing — not multi-model", r)
        _require(r["wrong_route"] == 0,
                 "request served by a wrong-model replica", r)
        _require(r["replicas_final"] >= 1,
                 "a model group lost its last replica", r)
    _require(hot[0]["replicas_final"] > hot[0]["replicas_start"],
             "SLO-violating group did not gain a replica", hot[0])
    _require(idle[0]["replicas_final"] < idle[0]["replicas_start"],
             "idle group did not shrink", idle[0])
    # the rebalance was capacity-neutral: nothing scaled past the
    # partition
    _require(sum(r["replicas_final"] for r in rows) <= rows[0]["capacity"],
             "groups exceed the partition capacity", rows)


def check_paged(rows: list) -> None:
    """bench_inference_scaling --paged: one row per engine (slot pool,
    paged gather round-trip, paged direct kernel), identical greedy
    tokens across all three, and the paged engines must demonstrate what
    paging buys at memory parity — concurrency above the slot pool's
    ``max_num_seqs`` ceiling, physical-block sharing (refcount > 1
    somewhere at peak), at least one copy-on-write divergence, live
    free/reserved block gauges, and a DIRECT decode path no slower than
    the gather/scatter round-trip it replaced.  The service rows carry the
    per-group ``block_telemetry`` aggregate out of
    ``ReplicaSet.stats()`` — the numbers the router's headroom weighting
    runs on."""
    eng_rows = [r for r in rows if r.get("scenario") == "paged_compare"]
    svc_rows = [r for r in rows if r.get("scenario") == "paged_service"]
    _require(len(eng_rows) == 3, "expected one row per engine", eng_rows)
    by = {r.get("engine"): r for r in eng_rows}
    _require(set(by) == {"monolithic", "paged_gather", "paged"},
             "rows must cover all three engines", sorted(by))
    for r in eng_rows:
        _require(r.get("requests", 0) > 0, "engine served nothing", r)
        _require(r.get("tokens_match") is True,
                 "paged and slot-pool engines disagree on greedy tokens", r)
    mono, gather, direct = by["monolithic"], by["paged_gather"], by["paged"]
    _require(gather.get("decode_mode") == "gather"
             and direct.get("decode_mode") == "direct",
             "paged rows mislabel their decode mode", eng_rows)
    for paged in (gather, direct):
        _require(paged["peak_concurrent"] > mono["max_num_seqs"],
                 "paged engine never admitted past the slot ceiling", paged)
        _require(paged.get("shared_block_peak", 0) > 0,
                 "no physical-block sharing observed", paged)
        _require(paged.get("cow_copies", 0) > 0,
                 "no copy-on-write divergence observed", paged)
        # live gauges: at quiescence nothing is reserved and the pool
        # holds a sane free count (residency retention may keep blocks)
        _require(paged.get("free_blocks") is not None
                 and 0 <= paged["free_blocks"] <= paged["num_blocks"],
                 "free_blocks gauge missing or out of range", paged)
        _require(paged.get("reserved_blocks") == 0,
                 "blocks still reserved at quiescence", paged)
    # direct decode must not regress the gather round-trip it replaced;
    # the 0.9 factor only absorbs CI timer noise (the bench margin is
    # typically > 1.1x in direct's favor)
    _require(direct.get("decode_tokens_per_s", 0)
             >= 0.9 * gather.get("decode_tokens_per_s", 0),
             "direct paged decode slower than the gather round-trip",
             {"direct": direct.get("decode_tokens_per_s"),
              "gather": gather.get("decode_tokens_per_s")})
    # per-group telemetry out of ReplicaSet.stats(): the router's
    # headroom-weighting inputs must survive the full service pipeline
    _require(bool(svc_rows), "no paged_service telemetry rows", rows)
    for r in svc_rows:
        tel = r.get("block_telemetry")
        _require(isinstance(tel, dict),
                 "service group reported no block_telemetry", r)
        _require({"free_blocks", "total_blocks", "shared_blocks",
                  "cow_copies"} <= set(tel),
                 "block_telemetry missing keys", tel)
        _require(0 <= tel["free_blocks"] <= tel["total_blocks"],
                 "free_blocks out of range", tel)
        _require(tel.get("reporting_replicas", 0) >= 1,
                 "no replica reported block telemetry", tel)


def check_disagg(rows: list) -> None:
    """bench_inference_scaling --disagg: one ``disagg_compare`` row per
    mode (unified | disagg) at EQUAL replica count plus the
    ``disagg_fallback`` row.  Gates the tentpole claims: greedy tokens
    identical to the single-engine reference in both modes (the KV
    handoff moves state bit-exactly), every disagg request finished on a
    decode replica via handoff (zero wrong-role completions, handoff
    count covers the load), per-phase windows are PURE (the prefill
    group never observes ITL, the decode group never observes TTFT),
    disaggregation beats unified by >= 1.2x on BOTH TTFT p95 and ITL
    p95, and the block-exhausted decode pool fell back to recompute —
    completed requests, never failures."""
    cmp_rows = [r for r in rows if r.get("scenario") == "disagg_compare"]
    fb_rows = [r for r in rows if r.get("scenario") == "disagg_fallback"]
    by = {r.get("mode"): r for r in cmp_rows}
    _require(set(by) == {"unified", "disagg"},
             "expected one row per mode", sorted(by))
    uni, dis = by["unified"], by["disagg"]
    _require(uni.get("replicas") == dis.get("replicas"),
             "modes compared at unequal replica counts",
             {"unified": uni.get("replicas"), "disagg": dis.get("replicas")})
    for r in cmp_rows:
        _require(r.get("requests", 0) > 0, "mode served nothing", r)
        _require(r.get("tokens_match") is True,
                 "mode disagrees with the reference greedy tokens", r)
        _require(r.get("ttft_p95_ms") and r.get("itl_p95_ms"),
                 "mode is missing a per-phase p95", r)
        _require(r.get("wrong_role", 1) == 0,
                 "request completed on a wrong-role replica", r)
    _require(dis.get("handoffs", 0) >= dis["requests"],
             "not every disagg request was handed off",
             {"handoffs": dis.get("handoffs"),
              "requests": dis["requests"]})
    pg = dis.get("per_group") or {}
    roles = {gs.get("role") for gs in pg.values()}
    _require({"prefill", "decode"} <= roles,
             "disagg row lacks a prefill/decode group pair", sorted(roles))
    for g, gs in pg.items():
        if gs.get("role") == "prefill":
            _require(gs.get("ttft_p95_ms") is not None,
                     "prefill group observed no TTFT", {g: gs})
            _require(gs.get("itl_p95_ms") is None,
                     "prefill group observed ITL — phase window leaked",
                     {g: gs})
            _require(gs.get("handoff_exports", 0) > 0,
                     "prefill group exported nothing", {g: gs})
        if gs.get("role") == "decode":
            _require(gs.get("itl_p95_ms") is not None,
                     "decode group observed no ITL", {g: gs})
            _require(gs.get("ttft_p95_ms") is None,
                     "decode group observed TTFT — phase window leaked",
                     {g: gs})
    _require(dis.get("ttft_speedup", 0) >= 1.2,
             "disaggregation did not improve TTFT p95 by >= 1.2x",
             {"ttft_speedup": dis.get("ttft_speedup"),
              "unified_ms": uni.get("ttft_p95_ms"),
              "disagg_ms": dis.get("ttft_p95_ms")})
    _require(dis.get("itl_speedup", 0) >= 1.2,
             "disaggregation did not improve ITL p95 by >= 1.2x",
             {"itl_speedup": dis.get("itl_speedup"),
              "unified_ms": uni.get("itl_p95_ms"),
              "disagg_ms": dis.get("itl_p95_ms")})
    _require(len(fb_rows) == 1, "expected one disagg_fallback row", rows)
    fb = fb_rows[0]
    _require(fb.get("recomputes", 0) >= 1,
             "block-exhausted decode pool never exercised recompute", fb)
    _require(fb.get("completed", 0) == fb.get("exports", 0) + 1,
             "fallback lost a request (exports + occupant != completed)",
             fb)
    _require(fb.get("tokens_match") is True,
             "recomputed sequences disagree with reference tokens", fb)


def check_specdecode(rows: list) -> None:
    """bench_inference_scaling --speculative: three streams over the same
    prompts (vanilla / high_acceptance / low_acceptance), all three
    transcripts token-for-token identical (the greedy-equivalence
    invariant speculative decoding must never trade away), the
    identity-padded high-acceptance stream actually speculating
    (acceptance ~1.0) AND beating vanilla by >= 1.3x, and the
    adversarial low-acceptance stream tripping the acceptance floor —
    session disabled — without degrading below vanilla (>= 0.9x; the
    0.1 allowance only absorbs CI timer noise on a 1.0x design
    target)."""
    by = {r.get("stream"): r for r in rows}
    _require(set(by) == {"vanilla", "high_acceptance", "low_acceptance"},
             "wrong stream set", sorted(by))
    for r in rows:
        _require(r.get("scenario") == "speculative",
                 "row mislabels its scenario", r)
        _require(r.get("tokens_match") is True,
                 "speculative streams disagree on greedy tokens", r)
        _require(r.get("decode_tokens_per_s", 0) > 0,
                 "stream decoded nothing", r)
    hi, lo = by["high_acceptance"], by["low_acceptance"]
    _require(by["vanilla"].get("proposed") == 0,
             "vanilla stream proposed draft tokens", by["vanilla"])
    _require(hi.get("enabled") is True,
             "high-acceptance session turned itself off", hi)
    _require(hi.get("proposed", 0) > 0,
             "high-acceptance session never proposed", hi)
    _require(hi.get("acceptance_rate", 0) >= 0.9,
             "identity-padded draft should verify near-perfectly", hi)
    _require(hi.get("speedup_vs_vanilla", 0) >= 1.3,
             "speculative decode did not pay for its draft",
             {"speedup": hi.get("speedup_vs_vanilla")})
    _require(lo.get("enabled") is False,
             "low-acceptance session failed to disable itself", lo)
    _require(lo.get("speedup_vs_vanilla", 0) >= 0.9,
             "disabled speculation degraded below vanilla",
             {"speedup": lo.get("speedup_vs_vanilla")})


def check_qos(rows: list) -> None:
    """bench_agentic --qos: three phases (unloaded high-class baseline;
    contended QoS off; contended QoS on).  Gates the tentpole claims:
    high-class p95 under saturating low-class load stays <= 1.3x the
    unloaded baseline (isolation), low-class throughput under QoS stays
    >= 0.8x its no-QoS run (weighted fairness is work-conserving, not
    starvation), batch FUNCTION tasks all complete on the shared ledger,
    every phase's per-tenant ledger conserves (requests == completed +
    errors) with ZERO rows for tenants that phase never ran
    (cross-tenant bleed), and the QoS phase's preemptions were all
    resumed (token-identity is separately property-tested)."""
    by = {r.get("phase"): r for r in rows}
    _require(set(by) == {"baseline_high", "no_qos", "qos"},
             "wrong phase set", sorted(by))
    base, noq, q = by["baseline_high"], by["no_qos"], by["qos"]
    for r in rows:
        _require(r.get("scenario") == "qos_campaign",
                 "row mislabels its scenario", r)
        _require(r.get("high_decisions", 0) > 0,
                 "phase completed no high-class decisions", r)
        _require(r.get("decision_errors") == 0,
                 "a decision request failed", r)
        _require(not r.get("agent_errors"),
                 "an agent thread crashed", r)
        _require(r.get("batch_tasks", 0) > 0
                 and r["batch_completed"] == r["batch_tasks"],
                 "batch FUNCTION leg did not complete on the shared "
                 "ledger", r)
        _require(r.get("high_p95_s"), "phase has no high-class p95", r)
        # zero cross-tenant accounting: exactly the tenants this phase
        # ran, and each tenant's ledger conserves
        pt = r.get("per_tenant") or {}
        _require(sorted(pt) == r.get("expected_tenants"),
                 "per-tenant rows do not match the tenants that ran",
                 {"phase": r.get("phase"), "saw": sorted(pt),
                  "expected": r.get("expected_tenants")})
        for tenant, ts in pt.items():
            _require(ts.get("requests") ==
                     ts.get("completed", 0) + ts.get("errors", 0),
                     "tenant ledger does not conserve",
                     {"phase": r.get("phase"), tenant: ts})
    _require(base.get("qos") is True and q.get("qos") is True,
             "baseline/qos phases must run with QoS armed", rows)
    _require(noq.get("qos") is False,
             "no_qos phase ran with QoS armed", noq)
    for r in (noq, q):
        _require(r.get("low_decisions", 0) > 0,
                 "contended phase completed no low-class decisions", r)
        _require(r.get("low_throughput_per_s"),
                 "contended phase has no low-class throughput", r)
    # the isolation gate: saturating low-class load may not blow the
    # high class past 1.3x its unloaded p95 once QoS is on
    _require(q["high_p95_s"] <= 1.3 * base["high_p95_s"],
             "QoS failed to isolate the high class",
             {"qos_p95_s": q["high_p95_s"],
              "baseline_p95_s": base["high_p95_s"]})
    # work conservation: protecting the high class must not starve the
    # low class below 80% of what it got with QoS off
    _require(q["low_throughput_per_s"]
             >= 0.8 * noq["low_throughput_per_s"],
             "QoS starved the low class",
             {"qos_tp": q["low_throughput_per_s"],
              "no_qos_tp": noq["low_throughput_per_s"]})
    qc = q.get("qos_counters")
    _require(isinstance(qc, dict), "QoS phase reported no counters", q)
    _require(qc.get("reporting_replicas", 0) >= 1,
             "no replica reported QoS counters", qc)
    _require(qc.get("engine_preemptions", 0)
             == qc.get("engine_preempt_resumes", 0),
             "a preempted sequence never resumed", qc)
    _require(noq.get("qos_counters") is None,
             "QoS-off phase still carries a scheduler", noq)


CHECKS = {
    "affinity": check_affinity,
    "autoscale": check_autoscale,
    "multimodel": check_multimodel,
    "paged": check_paged,
    "specdecode": check_specdecode,
    "disagg": check_disagg,
    "qos": check_qos,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("kind", choices=sorted(CHECKS))
    ap.add_argument("path", help="bench smoke JSON output")
    args = ap.parse_args(argv)
    with open(args.path) as f:
        rows = json.load(f)
    try:
        CHECKS[args.kind](rows)
    except CheckFailed as e:
        print(f"[check-bench-json] {args.kind}: FAIL — {e}",
              file=sys.stderr)
        return 1
    print(f"[check-bench-json] {args.kind}: ok ({len(rows)} rows)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
