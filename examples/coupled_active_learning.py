"""Coupled AI-HPC active learning (ROSE/DDSim analogue).

Rounds of: run simulations -> exchange results through the in-memory store ->
score with a surrogate -> pick the most promising region for the next round.

Run: PYTHONPATH=src python examples/coupled_active_learning.py
"""
import numpy as np

from repro.core import (ResourceDescription, Rhapsody, TaskDescription,
                        TaskKind)
from repro.core.coupling import make_store
from repro.substrate.simulation import heat_stencil, surrogate_eval


def main(rounds: int = 3, sims_per_round: int = 8):
    rh = Rhapsody(ResourceDescription(nodes=2, cores_per_node=8), n_workers=4)
    store = make_store("memory")
    try:
        center = 0
        for r in range(rounds):
            # 1. candidate simulations around the current best seed
            seeds = [center + i for i in range(sims_per_round)]

            def sim(key, seed):
                grid = heat_stencil(n=32, steps=4, seed=seed)
                store.put(key, grid.astype(np.float32).ravel()[:256])
                return True

            def score(key):
                data = store.get(key, timeout=10)
                return float(surrogate_eval(data[:64][None, :]).mean())

            descs = []
            score_uids = []
            for i, seed in enumerate(seeds):
                s = TaskDescription(kind=TaskKind.COUPLED, fn=sim,
                                    args=(f"r{r}s{i}", seed),
                                    task_type="sim")
                c = TaskDescription(kind=TaskKind.COUPLED, fn=score,
                                    args=(f"r{r}s{i}",),
                                    dependencies=[s.uid], task_type="score")
                descs.extend([s, c])
                score_uids.append(c.uid)
            rh.submit(descs)
            rh.wait([d.uid for d in descs])
            scores = [rh.result(u) for u in score_uids]
            best = int(np.argmax(scores))
            center = seeds[best]  # steer the next round (active learning)
            print(f"round {r}: best seed {center} "
                  f"score {scores[best]:.4f} "
                  f"(avg put {store.stats.summary()['avg_put_ms']:.3f} ms)")
        print("coupling overhead <",
              f"{store.stats.summary()['avg_get_ms']:.3f} ms/get")
    finally:
        store.close()
        rh.close()


if __name__ == "__main__":
    main()
