"""End-to-end driver: serve a small LM with batched requests (the paper's
workload kind) — one replicated inference service + router-driven dispatch.

The service is a single name backed by ``--replicas`` engine replicas; each
request is submitted as an INFERENCE task and the middleware routes it to a
replica via ``ExecutionPolicy.routing``:

  * ``random``       — uniform random spread,
  * ``round_robin``  — cycle through replicas,
  * ``balanced``     — token-aware: equalize cumulative prompt-token load
                       AND request count per replica (paper, Fig 5d),
  * ``least_loaded`` — additionally reads live per-replica queue depth, so
                       a backed-up replica sheds load,
  * ``prefix_affinity`` — sticky sessions: requests sharing a prompt
                       prefix (the first ``affinity_prefix_len`` tokens,
                       hashed) pin to the replica whose engine already
                       holds the matching KV cache, so multi-turn prompts
                       skip prefill for the resident prefix; spills to the
                       least-loaded replica when the sticky one is backed
                       up past ``affinity_spill_factor``.  Per-replica
                       ``prefix_hits``/``prefix_misses`` land in
                       ``ReplicaSet.stats()``.

Replication knobs (see ``repro.core.policy.ExecutionPolicy``):
``replicas`` sets the default replica count for services that leave
``ServiceDescription.replicas`` unset; ``autoscale=True`` with
``autoscale_{min,max}_replicas`` / ``autoscale_{high,low}_depth`` grows and
shrinks replica sets from sustained per-replica queue depth.  Each replica
restarts independently on crash with exponential backoff
(``restart_backoff_s`` doubling up to ``restart_backoff_max_s``), giving up
after ``restart_max_attempts`` consecutive crashes so a broken replica
degrades the set instead of hot-looping; in-flight requests replay on the
restarted replica.

Multi-model serving (``--multi-model``): ONE replica set serves a "chat"
model and a smaller "draft" model — each replica is tagged with its model
group, each request addresses a model by payload tag
(``{"model": "draft", ...}``), and the router only considers that group's
replicas, so a request can never land on a wrong-model engine.  Per-group
request counts, latency, and ledger claims land in
``ReplicaSet.stats()["per_group"]``.

KV paging (``--paged``/``--no-paged``, default auto = ON for the demo's
dense config): replicas run the block-paged engine — admission by
free-block count, chunked prefill interleaved with decode, copy-on-write
prefix sharing, and direct paged decode (no gathered-view round-trip).
``--block-size``/``--num-blocks`` tune the pool; per-group free/shared
block telemetry lands in ``ReplicaSet.stats()["per_group"]
["block_telemetry"]`` and is printed after the run.  Works with
``--multi-model`` (both groups get the same paging knobs).

Cross-group speculative decoding (``--speculative``, implies
``--multi-model``): the draft group becomes the chat group's proposer —
``role="draft"``/``paired_with="chat"`` aliases both onto one routing
namespace and lets the ``weighted_capacity`` autoscaler scale the draft's
entitlement by measured acceptance (``min_replicas=0``: a useless draft
scales away entirely), and every chat replica runs a ``SpecDecodeSession``
(draft proposes ``--spec-k`` tokens per round, target verifies them in one
extend; greedy output identical to target-only decode).  All requests
address the chat model; per-group proposed/accepted/acceptance land in
``ReplicaSet.stats()["per_group"]`` and are printed after the run.

Run: PYTHONPATH=src python examples/serve_llm.py [--requests 24] [--replicas 2]
     PYTHONPATH=src python examples/serve_llm.py --multi-model --replicas 3
     PYTHONPATH=src python examples/serve_llm.py --paged --block-size 16
     PYTHONPATH=src python examples/serve_llm.py --speculative --spec-k 4
"""
import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.core import (ExecutionPolicy, ResourceDescription, Rhapsody,
                        ServiceDescription, TaskDescription, TaskKind)
from repro.core.router import ROUTERS
from repro.serving.client import llm_model_group, llm_service_factory


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--replicas", "--services", dest="replicas", type=int,
                    default=2)
    ap.add_argument("--routing", default="balanced", choices=tuple(ROUTERS))
    ap.add_argument("--multi-model", action="store_true",
                    help="serve a chat + draft model pair from ONE "
                         "replica set (weights 2:1), requests addressed "
                         "per model")
    ap.add_argument("--speculative", action="store_true",
                    help="arm cross-group speculative decoding on the "
                         "chat group (implies --multi-model): the draft "
                         "group proposes, chat replicas verify")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft proposals per speculative round")
    ap.add_argument("--paged", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="block-paged KV engine per replica (default auto: "
                         "ON for dense/moe configs; --no-paged forces the "
                         "slot pool)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV positions per physical block (paged)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="physical KV blocks per replica; default matches "
                         "the slot pool's memory budget (paged)")
    args = ap.parse_args()

    cfg = get_config("rhapsody-demo")
    rh = Rhapsody(ResourceDescription(nodes=max(2, args.replicas),
                                      cores_per_node=16),
                  policy=ExecutionPolicy(routing=args.routing),
                  n_workers=2)
    model_names = []
    try:
        engine_kw = dict(max_num_seqs=4, max_len=256,
                         prefill_buckets=(32, 64, 128),
                         # None = auto-resolve per config (see LLMServicer)
                         paged=args.paged, block_size=args.block_size,
                         num_blocks=args.num_blocks)
        if args.multi_model or args.speculative:
            # two model configs, one service: the draft model is the same
            # family scaled down (a speculative-decoding-style sidecar)
            draft_cfg = cfg.scaled(n_layers=2, d_model=64, n_heads=4,
                                   n_kv_heads=2, head_dim=16, d_ff=128)
            if args.speculative:
                # the sidecar becomes a real proposer: every chat replica
                # verifies its spec_k-token proposals in one extend, the
                # draft group's entitlement tracks measured acceptance
                draft_group = llm_model_group(
                    "draft", draft_cfg, weight=1.0, role="draft",
                    paired_with="chat", min_replicas=0, **engine_kw)
                chat_group = llm_model_group(
                    "chat", cfg, weight=2.0, draft_group=draft_group,
                    spec_k=args.spec_k, **engine_kw)
                model_names = ["chat"]  # drafts propose, they don't serve
            else:
                draft_group = llm_model_group("draft", draft_cfg,
                                              weight=1.0, **engine_kw)
                chat_group = llm_model_group("chat", cfg, weight=2.0,
                                             **engine_kw)
                model_names = ["chat", "draft"]
            replica_set = rh.add_service(ServiceDescription(
                name="llm", replicas=max(2, args.replicas),
                models=[chat_group, draft_group]))
            print(f"launched multi-model llm service "
                  f"{replica_set.group_counts()}:", rh.services.list())
        else:
            replica_set = rh.add_service(ServiceDescription(
                name="llm", replicas=args.replicas,
                factory=llm_service_factory(cfg, **engine_kw)))
            print(f"launched llm service x{args.replicas} replicas:",
                  rh.services.list())

        # heterogeneous prompt lengths -> token-aware routing matters
        rng = np.random.RandomState(0)
        lens = np.clip(np.exp(rng.normal(3.2, 0.7, args.requests)), 8,
                       120).astype(int)
        prompts = [list(rng.randint(0, cfg.vocab, size=int(L)))
                   for L in lens]

        def payload(i, p):
            out = {"prompt": p, "max_new_tokens": 16}
            if model_names:
                out["model"] = model_names[i % len(model_names)]
            return out

        descs = [TaskDescription(kind=TaskKind.INFERENCE, service="llm",
                                 payload=payload(i, p),
                                 task_type="inference")
                 for i, p in enumerate(prompts)]
        t0 = time.perf_counter()
        uids = rh.submit(descs)
        if not rh.wait(uids, timeout=600):
            raise TimeoutError("inference stream timed out")
        results = [rh.result(u) for u in uids]
        dt = time.perf_counter() - t0
        tokens = sum(len(r["tokens"]) + r["n_prompt"] for r in results)
        ttfts = [r["ttft_s"] for r in results if r["ttft_s"]]
        per = [p["requests"] for p in replica_set.stats()["per_replica"]]
        print(f"served {len(results)} requests in {dt:.2f}s "
              f"({tokens / dt:.0f} tok/s, routing={args.routing})")
        print(f"mean TTFT {np.mean(ttfts) * 1e3:.0f} ms; "
              f"p95 latency {np.percentile([r['latency_s'] for r in results], 95):.2f}s; "
              f"per-replica requests {per}")
        if model_names:
            per_group = replica_set.stats()["per_group"]
            print("per-model groups:",
                  {g: {"replicas": s["replicas"],
                       "requests": s["requests"], "cores": s["cores"]}
                   for g, s in per_group.items()})
        if args.speculative:
            per_group = replica_set.stats()["per_group"]
            print("speculative decode per group:",
                  {g: {"role": s.get("role"),
                       "proposed": s.get("proposed"),
                       "accepted": s.get("accepted"),
                       "acceptance": s.get("acceptance_rate")}
                   for g, s in per_group.items()})
        btel = {g: s.get("block_telemetry")
                for g, s in replica_set.stats()["per_group"].items()}
        if any(t is not None for t in btel.values()):
            print("paged-block telemetry per group:",
                  {g: {"free": t["free_blocks"], "total": t["total_blocks"],
                       "shared": t["shared_blocks"], "cow": t["cow_copies"]}
                   for g, t in btel.items() if t is not None})
        if args.routing == "prefix_affinity":
            stats = replica_set.stats()
            hits, misses = stats["prefix_hits"], stats["prefix_misses"]
            print(f"prefix-affinity hit rate "
                  f"{hits / max(1, hits + misses):.2f} "
                  f"({hits} hits / {misses} misses)")
    finally:
        rh.close()


if __name__ == "__main__":
    main()
