"""End-to-end driver: serve a small LM with batched requests (the paper's
workload kind) — persistent inference services + token-aware routing.

Run: PYTHONPATH=src python examples/serve_llm.py [--requests 24] [--services 2]
"""
import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.core import ResourceDescription, Rhapsody, ServiceDescription
from repro.core.router import make_router
from repro.serving.client import llm_service_factory


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--services", type=int, default=2)
    ap.add_argument("--routing", default="balanced",
                    choices=("random", "round_robin", "balanced"))
    args = ap.parse_args()

    cfg = get_config("rhapsody-demo")
    rh = Rhapsody(ResourceDescription(nodes=args.services, cores_per_node=8),
                  n_workers=2)
    try:
        eps = [rh.add_service(ServiceDescription(
            name=f"llm{i}", factory=llm_service_factory(
                cfg, max_num_seqs=4, max_len=256,
                prefill_buckets=(32, 64, 128), seed=i)))
            for i in range(args.services)]
        print(f"launched {args.services} model services:",
              rh.services.list())

        # heterogeneous prompt lengths -> token-aware balanced routing
        rng = np.random.RandomState(0)
        lens = np.clip(np.exp(rng.normal(3.2, 0.7, args.requests)), 8,
                       120).astype(int)
        prompts = [list(rng.randint(0, cfg.vocab, size=int(L)))
                   for L in lens]
        router = make_router(args.routing)
        assign = router.assign(prompts, args.services, cost=len)

        t0 = time.perf_counter()
        futs = []
        for si, idxs in enumerate(assign):
            for i in idxs:
                futs.append(eps[si].request(
                    {"prompt": prompts[i], "max_new_tokens": 16}))
        results = [f.result(timeout=600) for f in futs]
        dt = time.perf_counter() - t0
        tokens = sum(len(r["tokens"]) + r["n_prompt"] for r in results)
        ttfts = [r["ttft_s"] for r in results if r["ttft_s"]]
        print(f"served {len(results)} requests in {dt:.2f}s "
              f"({tokens / dt:.0f} tok/s, routing={args.routing})")
        print(f"mean TTFT {np.mean(ttfts) * 1e3:.0f} ms; "
              f"p95 latency {np.percentile([r['latency_s'] for r in results], 95):.2f}s")
    finally:
        rh.close()


if __name__ == "__main__":
    main()
