"""Quickstart: RHAPSODY middleware in ~40 lines.

Run: PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (ResourceDescription, ResourceRequirements, Rhapsody,
                        TaskDescription, TaskKind)
from repro.substrate.simulation import heat_stencil, surrogate_eval


def main():
    # declare resources (virtual nodes/cores/gpus) and start the middleware
    rh = Rhapsody(ResourceDescription(nodes=4, cores_per_node=8,
                                      gpus_per_node=2), n_workers=4)
    try:
        # a multi-rank "MPI" simulation feeding a GPU-tagged surrogate
        sim = TaskDescription(
            kind=TaskKind.EXECUTABLE, fn=heat_stencil,
            kwargs={"n": 64, "steps": 8},
            requirements=ResourceRequirements(ranks=4, cores_per_rank=2),
            task_type="mpi_sim")
        score = TaskDescription(
            fn=surrogate_eval, kwargs={"dim": 32},
            requirements=ResourceRequirements(gpus_per_rank=1),
            task_type="gpu_surrogate", dependencies=[sim.uid])
        # plus a bag of fine-grained analysis tasks running concurrently
        others = [TaskDescription(fn=surrogate_eval,
                                  kwargs={"dim": 8, "seed": i},
                                  task_type="analysis") for i in range(32)]

        uids = rh.submit([sim, score] + others)
        rh.wait(uids)
        print("simulation grid:", rh.result(sim.uid).shape)
        print("surrogate score:", float(rh.result(score.uid).mean()))
        print("peak heterogeneity width:", rh.events.peak_hw())
        print("throughput: %.0f tasks/s" % rh.events.throughput())
    finally:
        rh.close()


if __name__ == "__main__":
    main()
