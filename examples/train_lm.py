"""Train the demo LM for a few hundred steps with checkpoint/restart.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 200] [--resume]
"""
import argparse
import os

import jax

from repro.configs import get_config
from repro.models import get_model, make_batch
from repro.training.checkpoint import Checkpointer
from repro.training.optim import OptimizerConfig
from repro.training.train import TrainConfig, init_state, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/rhapsody_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config("rhapsody-demo")
    api = get_model(cfg)
    tcfg = TrainConfig(
        global_batch=args.batch, seq_len=args.seq, microbatches=2,
        optimizer=OptimizerConfig(lr=3e-3, warmup_steps=20,
                                  decay_steps=args.steps),
        checkpoint_every=50)
    ck = Checkpointer(args.ckpt_dir, keep=2)

    state, _ = init_state(jax.random.PRNGKey(0), api, cfg, tcfg.optimizer)
    start = 0
    if args.resume:
        restored, start = ck.restore_latest(state)
        if restored is not None:
            state = restored
            print(f"resumed from step {start}")

    def data():
        k = jax.random.PRNGKey(1234)
        while True:
            k, s = jax.random.split(k)
            yield make_batch(cfg, args.batch, args.seq, s)

    def log(step, m):
        print(f"step {step:4d}  loss {m['loss']:.4f}  "
              f"lr {m['lr']:.2e}  gnorm {m['grad_norm']:.2f}")

    state, hist = train_loop(api, cfg, tcfg, steps=args.steps,
                             data_iter=data(), state=state, start_step=start,
                             checkpointer=ck, log_every=20, on_metrics=log)
    print(f"final loss {hist[-1]['loss']:.4f} "
          f"(from {hist[0]['loss']:.4f}); checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
