"""Agentic AI-HPC campaign: LLM-driven agents realize decisions as HPC tasks.

Run: PYTHONPATH=src python examples/agentic_campaign.py
"""
import numpy as np

from repro.configs import get_config
from repro.core import (ResourceDescription, Rhapsody, ServiceDescription,
                        TaskDescription)
from repro.core.agent import AgentConfig, run_agent_population
from repro.serving.client import llm_service_factory
from repro.substrate.simulation import surrogate_eval


def main(n_agents: int = 4, n_decisions: int = 3):
    cfg = get_config("rhapsody-demo").scaled(n_layers=2, d_model=64,
                                             n_heads=4, n_kv_heads=2,
                                             head_dim=16, d_ff=128, vocab=512)
    rh = Rhapsody(ResourceDescription(nodes=4, cores_per_node=8), n_workers=4)
    try:
        rh.add_service(ServiceDescription(
            name="planner", factory=llm_service_factory(
                cfg, max_num_seqs=8, max_len=64, prefill_buckets=(16,))))
        rng = np.random.RandomState(0)
        cfgs = [AgentConfig(
            name=f"agent{k}", service="planner", n_decisions=n_decisions,
            tasks_per_decision=2,
            decision_payload=lambda i: {
                "prompt": list(rng.randint(0, 512, 10)),
                "max_new_tokens": 4},
            make_task=lambda i, j: TaskDescription(
                fn=surrogate_eval, kwargs={"dim": 16, "seed": i * 7 + j},
                task_type="tool_run"))
            for k in range(n_agents)]
        out = run_agent_population(rh, cfgs)
        lags = rh.events.realization_lag()
        print(f"{out['agents']} agents, {out['decisions']} decisions "
              f"-> {out['tasks']} HPC tasks")
        print(f"decision->realization lag: mean {np.mean(lags):.3f}s, "
              f"max {np.max(lags):.3f}s (bounded)")
        print(f"peak ARR {max(r for _, r in rh.events.windowed_rate('RUNNING', 0.5)):.1f} tasks/s")
    finally:
        rh.close()


if __name__ == "__main__":
    main()
